"""EngineCore — the one executor state machine behind every paged-pool
serving backend.

``PagedServingEngine`` and ``SpatialServingEngine`` used to carry two
drifting copies of the identical serving scaffold: admission binding,
chunked prefill, the batched varlen prefill's phase A (pending-cursor
allocation) / phase A2 (same-tick prefix dedup) / wave split / commit,
the fused decode loop, lazy cold-page shedding, and preempt/swap-in.
Every scheduler-visible behavior now lives HERE, once, driven through a
small formal ``Backend`` protocol that covers only what genuinely
differs between a single page pool and a sharded mesh deployment:

* pool primitives — allocate a chunk's pages, look up / register prefix
  keys, drop references (``alloc_chunk`` / ``lookup_prefix`` /
  ``register_prefix`` / ``decref_page`` / ``release_table``);
* dispatch primitives — run one chunk, one batched wave, or one fused
  decode step on the device(s) (``dispatch_chunk`` / ``dispatch_wave``
  / ``decode_step``);
* swap hooks — gather page rows to the host and write them back
  (``gather_park`` / ``upload_park`` / ``page_in_extend``), with ONE
  payload layout (flat page axis) so the host ``SwapArea`` format is
  backend-agnostic and the lazy-shed machinery works everywhere.

``EngineCore`` implements the ``serving.scheduler.Executor`` protocol —
``engine.step()`` is one scheduler tick — and owns all host-side
sequence state: slot binding, block tables, prefill cursors, decode
budgets, the swap area. A backend owns only device state (pool slabs,
jitted kernels) and pool bookkeeping. New scheduler or engine features
(lazy shed, batched prefill, budget autotuning) therefore land once and
every backend inherits them; the spatial engine's lazy cold-page shed
exists purely because this class hosts the paged engine's.

Most callers should not touch this class directly — the front-door
``repro.serving.api.LLM`` wraps it (see docs/serving.md).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache import PoolExhausted, SwapArea, bucketing
from repro.obs import (NULL_TELEMETRY, DlzsAuditor, fold_snapshot,
                       fold_traffic, reconcile_refs)
from repro.serving import swap_policy
from repro.serving.engine import Request
from repro.serving.scheduler import (SLA_DEADLINES_MS, ExecFault,
                                     NeedPages, Scheduler, SchedulerCfg)
from repro.serving.swap_policy import PrefillProgress as _PrefillProgress


@runtime_checkable
class Backend(Protocol):
    """Device/pool primitives a serving backend provides to EngineCore.

    A backend is a *stateless policy-free* device driver: it never
    decides WHO runs — it allocates, dispatches, and moves page bytes
    when the core asks. All page addressing at this boundary is by
    GLOBAL logical page index ``j`` (a position in a sequence's block
    table); the backend maps ``j`` to whatever pool/shard owns it.
    """

    # -- static shape/config facts -------------------------------------
    cfg: object                  # model config (vocab, pattern, ...)
    params: object
    page_size: int
    max_batch: int
    eos_id: int
    greedy: bool
    temperature: float
    bucket_pow2: bool
    share: bool                  # effective prefix sharing
    keep_recent: int             # newest pages a lazy shed must keep
    batched: bool                # batched varlen prefill configured
    budget_tokens: Optional[int]  # flat-buffer width (one compile)
    batch_wp: Optional[int]      # past-arena width (per pool shard)
    decode_sparsity: Optional[dict]
    # Last decode step's sparsity telemetry: {"pages_total": resident
    # pages a dense gather would touch, "pages_hot": pages the bounded
    # DLZS hot-width selection kept, "shard_skips": shards that skipped
    # their psum merge}. None before the first decode; the core turns it
    # into engine_decode_pages_skipped_total /
    # engine_decode_shard_merges_skipped_total counters.

    # -- admission ------------------------------------------------------
    def check_capacity(self, rid: int, total_tokens: int,
                       need_pages: int) -> None:
        """Raise ValueError when the request could NEVER fit."""

    # -- pool primitives ------------------------------------------------
    def alloc_chunk(self, pf, start_page: int, n_need: int
                    ) -> tuple[list[int], list[int], bool]:
        """Share/allocate pages for global range [start_page,
        start_page+n_need). Returns (pages, fresh_globals, sharing);
        raises PoolExhausted (``.shard`` names a starved pool shard)."""

    def release_pages(self, pages: list[int], start_global: int) -> None:
        """Decref not-yet-committed chunk pages (globals from
        ``start_global``)."""

    def release_table(self, table: list[int]) -> None:
        """Drop a sequence's references (negative SHED entries skipped)."""

    def lookup_prefix(self, g: int, key: tuple) -> Optional[int]: ...

    def register_prefix(self, g: int, key: tuple, pid: int) -> None: ...

    def forget_prefix(self, g: int, pid: int) -> None:
        """Drop page ``pid``'s prefix-index entry (no-op when it was
        never registered). Fault recovery: a batched prefill registers
        fresh pages before the wave dispatch writes them (same-tick
        dedup), so a dispatch failure must un-register those pages or a
        later identical prompt would revive garbage."""

    def decref_page(self, g: int, pid: int) -> None: ...

    def register_prompt_pages(self, toks, table, fresh_globals,
                              start_page: int) -> None: ...

    def ref_of(self, table, j: int) -> int: ...

    def held_pages(self, table, shard: Optional[int]) -> int: ...

    def page_on_shard(self, j: int, shard: Optional[int]) -> bool:
        """Does freeing global page ``j`` relieve pool shard ``shard``?
        Single-pool backends always say True."""

    # -- prefill dispatch ------------------------------------------------
    def dispatch_chunk(self, pf, table, start: int, end: int, width: int,
                       last_idx: int, pages: list[int],
                       fresh_globals: list[int]):
        """Compute + scatter ONE chunk; returns the logits row of
        ``last_idx`` (legacy per-sequence path). May stay a device
        array — the core only materializes the FINAL chunk's row."""

    def arena_cost(self, past_pages: int) -> list[int]:
        """Per-pool-shard past-arena slots a lane with ``past_pages``
        past pages occupies in a batched wave."""

    def dispatch_wave(self, flat, seg, pos, past_len, last_index,
                      lanes: list[dict]) -> dict[int, np.ndarray]:
        """Run one batched varlen wave (shared flat buffers prepacked by
        the core; ``lanes`` carry per-slot tables/pages/fresh sets) and
        return {slot: host logits row}."""

    # -- decode ----------------------------------------------------------
    def decode_step(self, slots, tables, lengths) -> jax.Array:
        """Grow/COW tail pages, select hot pages, run the fused decode;
        returns device logits [max_batch, >=vocab]. Raises NeedPages."""

    def set_last_token(self, slot: int, tok: int) -> None: ...

    def get_last_token(self, slot: int) -> int: ...

    def commit_tokens(self, next_tokens: jax.Array) -> None:
        """Install the sampled tokens as the next decode input."""

    # -- shed / swap ------------------------------------------------------
    def hot_logical(self, table) -> set[int]:
        """Global logical indices the decode gather currently keeps hot."""

    def gather_park(self, table, js: list[int]):
        """Pull pages ``js`` to the host as a tree whose page axis (1) is
        flat payload order — one layout for every backend, so shed and
        swap payloads concatenate with ``concat_rows``."""

    def can_hold(self, park_js: list[int]) -> bool:
        """Cheap pre-check: could the pool(s) supply ``park_js`` now?"""

    def page_in_extend(self, park_js: list[int]):
        """Return a ``j -> fresh pid`` allocator for a page-in plan
        (scores pulled once up front). May raise PoolExhausted lazily."""

    def upload_park(self, rows, uploads: list[tuple[int, int, int]]
                    ) -> None:
        """Write payload rows back: ``uploads`` is [(payload position,
        global index j, physical id)]."""

    # -- observability ----------------------------------------------------
    page_bytes_full: int     # full-tree bytes one page carries (swap price)
    page_bytes_gather: int   # fp K/V bytes a decode gather reads per page
    page_bytes_int8: int     # int8 mirror-tier bytes per page (0: no tier)

    def stats(self) -> dict: ...

    def page_accounting(self) -> dict:
        """Host-side pool census: {capacity, live, free, cached, shared,
        unique, quantized_live, quantize_events, per_shard} (``per_shard``
        None for single-pool backends, else rows with a ``shard`` key)."""

    def pool_refs(self) -> dict:
        """(shard, pid) -> refcount for every live page — the watchdog
        reconciles this against what the engine's tables imply."""

    def owner_of(self, j: int) -> int:
        """Pool shard owning global logical page ``j`` (0: single pool)."""

    def audit_decode(self, slot: int, table, length: int
                     ) -> Optional[dict]:
        """Exact-attention audit probe over one decode sequence's full
        resident page set (see obs.audit); None at a page boundary."""


def concat_rows(a, b):
    """Join two flat-payload host row trees along the page axis."""
    return jax.tree.map(lambda x, y: np.concatenate([x, y], axis=1), a, b)


def _rows_bytes(rows) -> int:
    return 0 if rows is None else sum(
        leaf.nbytes for leaf in jax.tree.leaves(rows))


class EngineCore:
    """Scheduler-driven executor over a ``Backend``.

    Single-step flow (``step()`` = one scheduler tick):
      admit   — swap preempted sequences back in, bind waiting requests
                to free slots (no page allocation yet)
      prefill — with a ``SchedulerCfg.prefill_tokens`` budget: pack
                chunks of EVERY prefilling prompt (consecutive chunks
                merge) into ONE batched varlen dispatch; legacy path: up
                to ``prefill_per_step`` one-sequence chunk dispatches
      decode  — one fused decode step over every decode-phase slot;
                finished sequences are reaped and their pages released
    """

    def __init__(self, backend: Backend,
                 scfg: Optional[SchedulerCfg] = None,
                 rng: Optional[jax.Array] = None):
        self.backend = backend
        self.cfg = backend.cfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.sched = Scheduler(scfg or SchedulerCfg())
        if backend.batched and self.sched.cfg.prefill_tokens == "auto":
            chunk_tok = self.sched.cfg.chunk_pages * backend.page_size
            self.sched.attach_budget(lo=chunk_tok,
                                     hi=backend.budget_tokens,
                                     quantum=backend.page_size)

        self.swap_area = SwapArea()
        self.active: dict[int, Request] = {}       # slot -> request
        self.budget: dict[int, int] = {}           # decode tokens left
        self.tables: dict[int, list[int]] = {}     # slot -> block table
        self._pf: dict[int, _PrefillProgress] = {}  # slots mid-prefill
        self._prefill_done: list[tuple[int, Request]] = []  # finished at
        #                              prefill (budget 0): reaped next decode
        self._terminal: list[Request] = []  # aborted (cancelled/expired/
        #                              failed) requests not yet drained
        #                              through step()'s finished stream
        self.lengths = np.zeros((backend.max_batch,), np.int64)
        self.free = list(range(backend.max_batch))

        self.tel = getattr(backend, "tel", None) or NULL_TELEMETRY
        self._tick_no = 0
        self._compiled: set = set()       # dispatch kinds seen (compile
        #                                   detection via first-call timing)
        self._sched_seen: dict[str, int] = {}  # last counter sync values
        self.auditor = DlzsAuditor()      # sampled DLZS prediction audit
        self._quant_seen = 0              # last quantize_events sync value
        self._last_pages_hot: Optional[int] = None  # hot_set change events

    @property
    def params(self):
        return self.backend.params

    def attach_telemetry(self, tel) -> None:
        """Share one ``obs.Telemetry`` across the core, the scheduler,
        and the backend (backends emit shard-tagged arena events)."""
        self.tel = tel
        self.sched.tel = tel
        self.backend.tel = tel

    # -- queueing -----------------------------------------------------------

    def submit(self, req: Request):
        if req.max_len is not None and req.max_len <= len(req.prompt):
            raise ValueError(
                f"request {req.rid}: max_len {req.max_len} leaves no room "
                f"after a {len(req.prompt)}-token prompt")
        total = len(req.prompt) + req.max_tokens
        if req.max_len is not None:
            total = min(total, req.max_len)
        need = -(-total // self.backend.page_size)
        self.backend.check_capacity(req.rid, total, need)
        req.out = []
        if req.submit_t is None:
            req.submit_t = time.perf_counter()
        if self.sched.cfg.sla_deadlines and req.sla is not None:
            ttft_ms, e2e_ms = SLA_DEADLINES_MS.get(req.sla, (None, None))
            if req.ttft_deadline_ms is None:
                req.ttft_deadline_ms = ttft_ms
            if req.deadline_ms is None:
                req.deadline_ms = e2e_ms
        if self.tel.enabled:
            self.tel.timeline(req.rid, sla=getattr(req, "sla", None))
        self.sched.submit(req)

    @property
    def queue(self) -> list[Request]:
        """Waiting work (fresh + preempted), highest priority first."""
        return self.sched.queued_requests()

    # -- executor protocol: admission --------------------------------------

    def free_slot_available(self) -> bool:
        return bool(self.free)

    def exec_admit(self, req: Request) -> int:
        """Bind a request to a slot. Pages come later, chunk by chunk.

        A request carrying prior output is a recompute-resume: its emitted
        tokens are appended to the prompt and replayed through prefill
        (exact under greedy decode), with the final sampled token
        suppressed — it was already emitted before preemption."""
        slot = self.free.pop(0)
        out = req.out or []
        if out:
            prompt = np.concatenate(
                [np.asarray(req.prompt, np.int64),
                 np.asarray(out[:-1], np.int64)])
        else:
            prompt = np.asarray(req.prompt, np.int64)
        spans = bucketing.chunk_spans(
            len(prompt), self.backend.page_size, self.sched.cfg.chunk_pages,
            pow2=self.backend.bucket_pow2)
        share = self.backend.share
        self._pf[slot] = _PrefillProgress(
            prompt=prompt,
            toks=tuple(int(x) for x in prompt) if share else None,
            spans=spans, chunk=0, sharing=share,
            suppress_first=bool(out))
        self.tables[slot] = []
        self.active[slot] = req
        self.lengths[slot] = 0
        if self.tel.enabled:
            tl = self.tel.timeline(req.rid)
            now = time.perf_counter()
            if out:                        # recompute-mode resume
                tl.resume_ts.append(now)
            elif tl.admit_t is None:
                tl.admit_t = now
            self.tel.tracer.instant("admit", rid=req.rid, slot=slot,
                                    resume=bool(out))
            self.tel.recorder.record("admit", tick=self._tick_no,
                                     rid=req.rid, slot=slot,
                                     resume=bool(out))
        return slot

    def prefill_chunks_left(self, slot: int) -> int:
        pf = self._pf.get(slot)
        return 0 if pf is None else len(pf.spans) - pf.chunk

    def held_pages(self, slot: int, shard: Optional[int] = None) -> int:
        return self.backend.held_pages(self.tables.get(slot, ()), shard)

    # -- executor protocol: chunked prefill ---------------------------------

    def _alloc_chunk(self, slot: int, pf, start_page: int, n_need: int):
        """Backend allocation with pool pressure translated into the
        scheduler's NeedPages signal (shard-tagged when the backend's
        exhaustion names a starved pool shard)."""
        try:
            return self.backend.alloc_chunk(pf, start_page, n_need)
        except PoolExhausted as e:
            shard = getattr(e, "shard", None)
            if self.tel.enabled:
                self.tel.tracer.instant("need_pages", slot=slot,
                                        where="prefill", shard=shard,
                                        pages=n_need)
                self.tel.metrics.counter(
                    "engine_need_pages_total",
                    "pool-pressure signals raised").inc(where="prefill")
            raise NeedPages(slot, shard) from None

    def _finish_prefill(self, slot: int, pf, logits_row, done_out=None
                        ) -> None:
        """Prompt complete: emit the first token, enter decode phase (or
        reap immediately when the token budget is already spent)."""
        req = self.active[slot]
        if pf.suppress_first:
            tok = int(req.out[-1])
        else:
            tok = int(np.argmax(logits_row[:self.cfg.vocab]))
            req.out.append(tok)
        del self._pf[slot]
        self.lengths[slot] = len(pf.prompt)
        self.backend.set_last_token(slot, tok)
        self.budget[slot] = req.max_tokens - len(req.out)
        if self.tel.enabled and not pf.suppress_first:
            tl = self.tel.timeline(req.rid)
            if tl.first_token_t is None:
                tl.first_token_t = time.perf_counter()
        if done_out is not None:
            done_out.append(slot)
        if self.budget[slot] <= 0:     # e.g. max_tokens=1: done at prefill
            self.backend.release_table(self.tables.pop(slot))
            del self.active[slot]
            del self.budget[slot]
            self.lengths[slot] = 0
            self.free.append(slot)
            req.finish_reason = "done"
            self._prefill_done.append((slot, req))
            if self.tel.enabled:
                self._stamp_done(req, "done")

    def _stamp_done(self, req: Request, outcome: str) -> None:
        """Close a request's timeline and bump the finish counters."""
        tl = self.tel.timeline(req.rid)
        tl.done_t = time.perf_counter()
        tl.n_tokens = len(req.out or ())
        tl.outcome = outcome
        sla = getattr(req, "sla", None) or "default"
        self.tel.metrics.counter(
            "engine_requests_finished_total",
            "requests completed").inc(sla=sla)
        self.tel.metrics.counter(
            "engine_tokens_total",
            "tokens emitted by finished requests").inc(tl.n_tokens,
                                                       sla=sla)
        if tl.ttft is not None:
            self.tel.metrics.histogram(
                "engine_ttft_seconds",
                "time to first token").observe(tl.ttft, sla=sla)

    # -- lifecycle: cancellation / deadlines / quarantine --------------------

    _ABNORMAL_EVENT = {"cancelled": "cancel", "expired": "deadline_expired",
                       "failed": "quarantine"}

    def _finish_abnormal(self, req: Request, outcome: str,
                         reason: str) -> None:
        """Stamp a terminal CANCELLED/EXPIRED/FAILED state. The request
        joins ``_terminal`` so the next step() surfaces it through the
        finished stream (the LLM front door closes its record there).
        Aborts bump their own counter, NOT the finished/token counters —
        per-SLA goodput only ever counts work that completed."""
        req.finish_reason = outcome
        self._terminal.append(req)
        if not self.tel.enabled:
            return
        tl = self.tel.timeline(req.rid)
        if tl.done_t is None:
            tl.done_t = time.perf_counter()
        tl.n_tokens = len(req.out or ())
        tl.outcome = outcome
        sla = getattr(req, "sla", None) or "default"
        self.tel.metrics.counter(
            "engine_requests_aborted_total",
            "requests ended abnormally").inc(sla=sla, outcome=outcome)
        self.tel.recorder.record(
            self._ABNORMAL_EVENT[outcome], tick=self._tick_no,
            rid=req.rid, reason=reason, tokens=len(req.out or ()))

    def _teardown_slot(self, slot: int) -> Request:
        """Release everything a bound slot holds: pending chunk pages,
        the block table (COW-shared pages decref only — another owner
        keeps them live), any lazy-shed swap payload, budget, length."""
        req = self.active.pop(slot)
        table = self.tables.pop(slot)
        pf = self._pf.pop(slot, None)
        swap_policy.release_pending(
            pf, lambda pgs: self.backend.release_pages(pgs, len(table)))
        self.backend.release_table(table)
        self.swap_area.discard(req.rid)
        self.budget.pop(slot, None)
        self.lengths[slot] = 0
        self.free.append(slot)
        return req

    def cancel(self, rid: int, *, outcome: str = "cancelled",
               reason: str = "client") -> bool:
        """Terminate a request wherever it is — mid-prefill, mid-decode,
        waiting fresh, or fully swapped out. Frees every page it solely
        owns (shared pages decref), discards parked payloads, stamps the
        terminal timeline state. False when the rid is not in flight."""
        for slot, req in list(self.active.items()):
            if req.rid == rid:
                self.sched.drop_running_slot(slot)
                self._teardown_slot(slot)
                self._finish_abnormal(req, outcome, reason)
                return True
        req = self.sched.drop_waiting(rid)
        if req is not None:
            payload = self.swap_area.discard(rid)
            if payload:
                # a parked sequence still holds refs on its shared pages
                for j, pid in payload.get("kept", ()):
                    self.backend.decref_page(j, pid)
            self._finish_abnormal(req, outcome, reason)
            return True
        return False

    def exec_abort(self, req: Request, outcome: str, reason: str) -> None:
        """Scheduler-initiated terminal state for a NON-running request
        (quarantine past the retry budget, admission shed)."""
        payload = self.swap_area.discard(req.rid)
        if payload:
            for j, pid in payload.get("kept", ()):
                self.backend.decref_page(j, pid)
        self._finish_abnormal(req, outcome, reason)

    def _expire_deadlines(self) -> None:
        """Sweep TTFT/end-to-end budgets over everything in flight; runs
        at the top of every step so an expired request never consumes
        another tick's worth of pool or dispatch."""
        now = time.perf_counter()
        expired = [req.rid for req in self.active.values()
                   if req.deadline_exceeded(now)]
        expired += [w.req.rid for w in self.sched.waiting
                    if w.req.deadline_exceeded(now)]
        for rid in expired:
            self.cancel(rid, outcome="expired", reason="deadline")

    def _note_fault(self, slots, err: BaseException, where: str) -> None:
        if not self.tel.enabled:
            return
        kind = "fault_injected" if getattr(err, "is_injected", False) \
            else "fault"
        self.tel.recorder.record(kind, tick=self._tick_no, where=where,
                                 slots=list(slots),
                                 error=type(err).__name__)
        self.tel.metrics.counter(
            "engine_faults_total",
            "backend failures isolated to their requests").inc(
            where=where)

    def _purge_pending(self, slots) -> None:
        """Roll every listed slot's batched-prefill cursor back to the
        last committed chunk: un-register fresh pages phase A2 indexed
        (their content never landed — the dispatch failed) and release
        the pending allocation. The next attempt re-allocates cleanly."""
        for slot in slots:
            pf = self._pf.get(slot)
            if pf is None or pf.pending is None:
                continue
            pages, fresh, _ = pf.pending
            start_page = len(self.tables[slot])
            for g in fresh:
                self.backend.forget_prefix(g, pages[g - start_page])
            self.backend.release_pages(pages, start_page)
            pf.pending = None

    def exec_prefill_chunk(self, slot: int) -> bool:
        """Share/allocate + compute + scatter ONE chunk of ``slot``'s
        prompt. Returns True once the prompt is complete (slot enters
        decode). Raises NeedPages when the pool cannot supply the chunk."""
        pf = self._pf[slot]
        page = self.backend.page_size
        start, end, width = pf.spans[pf.chunk]
        start_page = start // page
        n_need = -(-end // page) - start_page
        pages, fresh_globals, sharing = self._alloc_chunk(
            slot, pf, start_page, n_need)
        pf.sharing = sharing
        table = self.tables[slot]
        table.extend(pages)
        t = len(pf.prompt)
        last = pf.chunk == len(pf.spans) - 1
        if self.tel.enabled and pf.chunk == 0:
            tl = self.tel.timeline(self.active[slot].rid)
            if tl.first_chunk_t is None:
                tl.first_chunk_t = time.perf_counter()

        logits = None
        if fresh_globals or last:  # fully-shared middle chunks skip compute
            last_idx = (t - 1 if last else end - 1) - start
            kind = ("chunk", width)
            try:
                with self.tel.tracer.span(
                        "prefill.chunk", slot=slot, width=width,
                        compile=kind not in self._compiled):
                    logits = self.backend.dispatch_chunk(
                        pf, table, start, end, width, last_idx, pages,
                        fresh_globals)
            except NeedPages:
                raise
            except Exception as err:
                # isolate to this request: its pages (all in the table
                # by now, none prefix-registered yet — the sequential
                # path registers after compute) fall with it in the
                # recompute preemption the scheduler now issues
                self._note_fault([slot], err, "prefill")
                raise ExecFault([slot], err, "prefill") from err
            self._compiled.add(kind)
            if self.backend.share and pf.toks is not None:
                self.backend.register_prompt_pages(pf.toks, table,
                                                   fresh_globals,
                                                   start_page)
        pf.chunk += 1
        if not last:
            return False
        self._finish_prefill(slot, pf, logits)
        return True

    # -- executor protocol: batched varlen chunk prefill --------------------

    def pending_chunk_widths(self, slot: int) -> list[int]:
        pf = self._pf[slot]
        return [w for _, _, w in pf.spans[pf.chunk:]]

    @staticmethod
    def _merged_span(pf, n: int) -> tuple[int, int, int]:
        """Span covering the next ``n`` CONSECUTIVE chunks as one varlen
        piece: non-final chunks are exactly full, so only the tail can
        pad — merged chunks behave exactly like one larger chunk."""
        start = pf.spans[pf.chunk][0]
        end = pf.spans[pf.chunk + n - 1][1]
        width = sum(w for _, _, w in pf.spans[pf.chunk:pf.chunk + n])
        return start, end, width

    def exec_prefill_chunk_batch(self, batch: list[tuple[int, int]]
                                 ) -> list[int]:
        """Advance every ``(slot, n_chunks)`` entry in ONE compiled
        varlen dispatch over a fixed ``[1, budget_tokens]`` flat buffer.

        Three phases: (A) allocate each slot's merged-span pages —
        idempotent via ``pf.pending``, so a NeedPages retry after
        preemption reuses what already succeeded; (A2) same-tick prefix
        dedup; (B) pack the spans back to back into the flat buffer
        (segment ids, absolute positions) and hand the wave to the
        backend's dispatch — fully prefix-shared non-final spans need no
        lanes at all; (C) commit: extend tables, advance cursors, emit
        first tokens for completed prompts. Nothing commits before the
        dispatch succeeds, so a phase-A NeedPages leaves every pending
        cursor untouched. In the rare case the packed spans' pasts
        overflow the fixed arena, phase B splits into several same-shape
        waves (still one compilation). Returns the slots entering
        decode."""
        page = self.backend.page_size
        pack_span = self.tel.tracer.span("prefill.pack", slots=len(batch))
        pack_span.__enter__()
        for slot, n in batch:                  # phase A: allocation
            pf = self._pf[slot]
            if pf.pending is not None:
                continue
            n = max(1, min(n, len(pf.spans) - pf.chunk))
            start, end, _ = self._merged_span(pf, n)
            start_page = start // page
            n_need = -(-end // page) - start_page
            try:
                pages, fresh_globals, sharing = self._alloc_chunk(
                    slot, pf, start_page, n_need)
            except NeedPages:
                pack_span.__exit__(None, None, None)
                raise
            pf.sharing = sharing
            pf.pending = (pages, fresh_globals, n)
            if self.tel.enabled and pf.chunk == 0:
                tl = self.tel.timeline(self.active[slot].rid)
                if tl.first_chunk_t is None:
                    tl.first_chunk_t = time.perf_counter()

        # Phase A2 — same-tick prefix dedup. Batched admission runs many
        # same-prefix prompts' chunks in ONE tick, so the ordinary
        # register-after-compute flow would never let them share (each
        # allocates before any registers). Once every allocation above
        # succeeded nothing can raise before the dispatch commits, so it
        # is safe to register fresh full prompt pages NOW and point later
        # slots in the batch at them — the owning lane's scatter writes
        # the content within this same dispatch.
        slots = [s for s, _ in batch]
        if self.backend.share:
            for slot in slots:
                pf = self._pf[slot]
                if pf.toks is None:
                    continue
                pages, fresh_globals, n = pf.pending
                start_page = pf.spans[pf.chunk][0] // page
                fresh_set = set(fresh_globals)
                new_fresh = []
                for cj, pid in enumerate(pages):
                    g = start_page + cj
                    if g not in fresh_set:
                        continue
                    end = (g + 1) * page
                    if end > len(pf.toks):
                        new_fresh.append(g)
                        continue
                    key = pf.toks[:end]
                    hit = self.backend.lookup_prefix(g, key)
                    if hit is not None:        # an earlier lane owns it
                        self.backend.decref_page(g, pid)
                        pages[cj] = hit
                    else:
                        self.backend.register_prefix(g, key, pid)
                        new_fresh.append(g)
                pf.pending = (pages, new_fresh, n)

        def is_last(slot):
            pf = self._pf[slot]
            return pf.chunk + pf.pending[2] == len(pf.spans)

        compute = [s for s in slots
                   if self._pf[s].pending[1] or is_last(s)]

        # wave split: spans whose combined past pages (or tokens, after a
        # pressure retry reshuffled the batch) overflow the fixed buffers
        # spill to a follow-up dispatch of the SAME compiled shape. Past
        # cost is per pool shard (a striped backend fills several arenas)
        waves: list[list[int]] = []
        cur: list[int] = []
        cur_p: Optional[list[int]] = None
        cur_t = 0
        for slot in compute:
            pf = self._pf[slot]
            start, _, width = self._merged_span(pf, pf.pending[2])
            cost = self.backend.arena_cost(start // page)
            if cur and (cur_t + width > self.backend.budget_tokens
                        or any(c + d > self.backend.batch_wp
                               for c, d in zip(cur_p, cost))):
                waves.append(cur)
                cur, cur_p, cur_t = [], None, 0
            cur.append(slot)
            cur_p = cost if cur_p is None \
                else [c + d for c, d in zip(cur_p, cost)]
            cur_t += width
        if cur:
            waves.append(cur)
        pack_span.args["waves"] = len(waves)
        pack_span.__exit__(None, None, None)
        if len(waves) > 1:
            self.tel.metrics.counter(
                "engine_wave_splits_total",
                "batched prefills split into extra waves").inc(
                len(waves) - 1)

        logits_by_slot: dict[int, np.ndarray] = {}
        for i, wave in enumerate(waves):       # phase B: dispatch(es)
            first = "wave" not in self._compiled
            try:
                with self.tel.tracer.span("prefill.dispatch", wave=i,
                                          lanes=len(wave), compile=first):
                    self._dispatch_chunk_wave(wave, logits_by_slot)
            except NeedPages:
                raise
            except Exception as err:
                # nothing has committed (phase C never ran): roll every
                # batch slot's pending cursor back — crucially
                # un-registering the phase-A2 prefix entries whose page
                # content this dispatch was supposed to write — and
                # blame only the failing wave's slots; the rest repack
                # and redispatch cleanly on the scheduler's retry
                self._purge_pending(slots)
                self._note_fault(wave, err, "prefill")
                raise ExecFault(wave, err, "prefill") from err
            self._compiled.add("wave")

        done: list[int] = []
        with self.tel.tracer.span("prefill.commit", slots=len(slots)):
            for slot in slots:                 # phase C: commit
                pf = self._pf[slot]
                pages, fresh_globals, n = pf.pending
                self.tables[slot].extend(pages)
                # prefix registration already happened in phase A2 — the
                # sole registration point, which is what makes same-tick
                # sharing safe (content lands via this dispatch's scatter)
                pf.pending = None
                pf.chunk += n
                if pf.chunk < len(pf.spans):
                    continue
                self._finish_prefill(slot, pf, logits_by_slot.get(slot),
                                     done_out=done)
        return done

    def _dispatch_chunk_wave(self, wave: list[int],
                             logits_by_slot: dict) -> None:
        """Pack one wave of merged spans into the shared flat buffer
        (tokens, segment ids, absolute positions, per-lane past lengths
        and last indices) and hand it to the backend dispatch, which
        adds its pool-specific past arena + scatter targets."""
        page = self.backend.page_size
        b_tok, lanes_n = self.backend.budget_tokens, self.backend.max_batch
        flat = np.zeros((b_tok,), np.int32)
        seg = np.full((b_tok,), -1, np.int32)
        pos = np.zeros((b_tok,), np.int32)
        past_len = np.zeros((lanes_n,), np.int32)
        last_index = np.zeros((lanes_n,), np.int32)
        cursor = 0
        lanes: list[dict] = []
        for slot in wave:
            pf = self._pf[slot]
            pages, fresh_globals, n = pf.pending
            start, end, width = self._merged_span(pf, n)
            last = pf.chunk + n == len(pf.spans)
            t = len(pf.prompt)
            flat[cursor:cursor + width] = bucketing.pad_tokens(
                pf.prompt[start:end], width)
            seg[cursor:cursor + width] = slot
            pos[cursor:cursor + width] = start + np.arange(width)
            last_index[slot] = cursor + (t - 1 if last else end - 1) \
                - start
            past_len[slot] = start
            lanes.append({"slot": slot, "table": self.tables[slot],
                          "pages": pages, "fresh": set(fresh_globals),
                          "start_page": start // page,
                          "base": cursor // page})
            cursor += width
        logits_by_slot.update(self.backend.dispatch_wave(
            flat, seg, pos, past_len, last_index, lanes))

    # -- executor protocol: decode ------------------------------------------

    def _decode_slots(self) -> list[int]:
        return [s for s in self.active if s not in self._pf]

    def exec_decode(self) -> list[tuple[int, Request]]:
        slots = self._decode_slots()
        if not slots:
            done_early, self._prefill_done = self._prefill_done, []
            return done_early
        # may raise NeedPages (tail-page growth) — drain the
        # prefill-finished list only once nothing can raise anymore.
        # The span covers dispatch THROUGH the host sync (np.asarray):
        # jit dispatch is async, so device time only shows at the sync.
        first = "decode" not in self._compiled
        with self.tel.tracer.span("decode.step", lanes=len(slots),
                                  compile=first):
            try:
                logits = self.backend.decode_step(slots, self.tables,
                                                  self.lengths)
            except NeedPages as e:
                if self.tel.enabled:
                    self.tel.tracer.instant("need_pages", slot=e.slot,
                                            where="decode",
                                            shard=e.shard)
                    self.tel.metrics.counter(
                        "engine_need_pages_total",
                        "pool-pressure signals raised").inc(where="decode")
                raise
            except Exception as err:
                # the fused step blames every decode slot — each falls
                # back to recompute replay (exact under greedy decode),
                # so innocents still finish with identical output
                self._note_fault(slots, err, "decode")
                raise ExecFault(slots, err, "decode") from err
            done_early, self._prefill_done = self._prefill_done, []
            logits = logits[:, :self.cfg.vocab]
            if self.backend.greedy:
                nxt = jnp.argmax(logits, axis=-1)
            else:
                self.rng, sub = jax.random.split(self.rng)
                nxt = jax.random.categorical(
                    sub, logits / self.backend.temperature, axis=-1)
            self.backend.commit_tokens(nxt)
            nxt_host = np.asarray(nxt)
        self._compiled.add("decode")
        sparsity = getattr(self.backend, "decode_sparsity", None)
        if self.tel.enabled and sparsity:
            skipped = sparsity["pages_total"] - sparsity["pages_hot"]
            self.tel.metrics.counter(
                "engine_decode_pages_considered_total",
                "resident pages a dense decode gather would have "
                "touched").inc(sparsity["pages_total"])
            if skipped > 0:
                self.tel.metrics.counter(
                    "engine_decode_pages_skipped_total",
                    "resident pages the bounded DLZS hot-width decode "
                    "gather left cold").inc(skipped)
                self.tel.metrics.counter(
                    "engine_decode_bytes_skipped_total",
                    "fp K/V bytes the bounded hot-width gather did NOT "
                    "read (measured bytes-not-gathered)").inc(
                    skipped * getattr(self.backend, "page_bytes_gather", 0))
            if sparsity.get("shard_skips"):
                self.tel.metrics.counter(
                    "engine_decode_shard_merges_skipped_total",
                    "per-step shards holding zero hot pages whose psum "
                    "contribution was skipped").inc(sparsity["shard_skips"])
            if sparsity["pages_hot"] != self._last_pages_hot:
                self.tel.recorder.record(
                    "hot_set", tick=self._tick_no,
                    pages_hot=sparsity["pages_hot"],
                    pages_total=sparsity["pages_total"])
                self._last_pages_hot = sparsity["pages_hot"]
        finished = done_early
        tel_on = self.tel.enabled
        now = time.perf_counter() if tel_on else 0.0
        for slot in slots:
            req = self.active[slot]
            tok = int(nxt_host[slot])
            req.out.append(tok)
            self.lengths[slot] += 1
            self.budget[slot] -= 1
            if tel_on:
                self.tel.timeline(req.rid).token_ts.append(now)
            limit = req.max_len
            done = (tok == self.backend.eos_id or self.budget[slot] <= 0
                    or (limit is not None
                        and self.lengths[slot] + 1 >= limit))
            if done:
                self.backend.release_table(self.tables.pop(slot))
                self.swap_area.discard(req.rid)   # lazily-shed pages
                del self.active[slot]
                del self.budget[slot]
                self.lengths[slot] = 0
                self.free.append(slot)
                req.finish_reason = "done"
                finished.append((slot, req))
                if tel_on:
                    self._stamp_done(req, "done")
        return finished

    # -- executor protocol: lazy shed / preemption / swap -------------------

    def exec_shed_cold(self, slot: int, shard: Optional[int] = None
                       ) -> int:
        """Lazy swap: park the slot's DLZS-cold uniquely-owned pages on
        the host while it KEEPS decoding. Only pages outside both the
        recent window and the current hot-page selection are shed — pages
        the decode gather was already skipping — so the victim's hot-set
        output is unchanged; the pool just gets its cold pages back.
        Table entries become the SHED sentinel; a later full preemption
        merges the shed payload into the ordinary swap payload. When the
        pressure names a starved pool shard, only pages owned there are
        shed (freeing elsewhere would not unblock the needy sequence).
        Returns pages freed (0: mid-prefill, or nothing sheddable)."""
        if slot in self._pf or slot not in self.tables:
            return 0                 # prefill still reads its past pages
        table = self.tables[slot]
        hot = self.backend.hot_logical(table)
        cands = swap_policy.shed_candidates(
            table, hot, int(self.lengths[slot]), self.backend.page_size,
            lambda j: self.backend.ref_of(table, j),
            keep_recent=self.backend.keep_recent)
        cands = [j for j in cands
                 if self.backend.page_on_shard(j, shard)]
        if not cands:
            return 0
        req = self.active[slot]
        with self.tel.tracer.span("shed", slot=slot, rid=req.rid,
                                  pages=len(cands), shard=shard):
            host = self.backend.gather_park(table, cands)
            state = swap_policy.merge_shed(
                {"rows": host, "park": list(cands)},
                self.swap_area.discard(req.rid), concat_rows)
            self.swap_area.put(req.rid, state, _rows_bytes(state["rows"]))
            for j in cands:
                self.backend.decref_page(j, table[j])
                table[j] = swap_policy.SHED
        if self.tel.enabled:
            self.tel.metrics.counter(
                "engine_pages_swapped_total",
                "pages moved between pool and host").inc(
                len(cands), dir="out", kind="shed")
            self.tel.metrics.counter(
                "engine_swap_bytes_total",
                "page bytes moved between pool and host").inc(
                _rows_bytes(host), dir="out", kind="shed")
            self.tel.recorder.record("shed", tick=self._tick_no,
                                     rid=req.rid, slot=slot,
                                     pages=len(cands), shard=shard)
        return len(cands)

    def exec_preempt(self, slot: int, swap: bool) -> bool:
        """Evict ``slot``. swap=True parks its page contents in the host
        SwapArea (resume = page-in); otherwise pages are dropped and the
        sequence recomputes from prompt + emitted tokens on re-admission.

        Shared-prefix-aware parking (swap_policy core): only uniquely-
        owned (ref-1) pages are gathered to the host. A page some other
        sequence also references keeps OUR reference while swapped — its
        content cannot be freed or rewritten underneath us, so resume
        reuses the same physical page with zero upload. Pages a lazy
        shed already parked merge into the payload."""
        req = self.active.pop(slot)
        table = self.tables.pop(slot)
        pf = self._pf.pop(slot, None)
        span = self.tel.tracer.span("preempt", slot=slot, rid=req.rid,
                                    swap=swap)
        span.__enter__()
        swap_policy.release_pending(
            pf, lambda pgs: self.backend.release_pages(pgs, len(table)))
        swapped = False
        if swap and table:
            kept, park, shed = swap_policy.partition_table(
                table, lambda j: self.backend.ref_of(table, j))
            # gather BEFORE decref: page content is only guaranteed
            # until the ids return to the free list
            with self.tel.tracer.span("swap_out", rid=req.rid,
                                      pages=len(park)):
                host = self.backend.gather_park(table, park) \
                    if park else None
            state = swap_policy.progress_state(
                req, pf, share=self.backend.share,
                length=int(self.lengths[slot]),
                last_token=self.backend.get_last_token(slot),
                budget=self.budget.get(slot, 0))
            state.update(rows=host, park=park, kept=kept,
                         n_pages=len(table))
            state = swap_policy.merge_shed(
                state, self.swap_area.discard(req.rid) if shed else None,
                concat_rows)
            self.swap_area.put(req.rid, state, _rows_bytes(state["rows"]))
            # release ONLY the parked pages; kept (shared) pages retain
            # this sequence's reference until it resumes
            for j in park:
                self.backend.decref_page(j, table[j])
            swapped = True
            if self.tel.enabled and park:
                self.tel.metrics.counter(
                    "engine_pages_swapped_total",
                    "pages moved between pool and host").inc(
                    len(park), dir="out", kind="preempt")
                self.tel.metrics.counter(
                    "engine_swap_bytes_total",
                    "page bytes moved between pool and host").inc(
                    _rows_bytes(host), dir="out", kind="preempt")
        else:
            self.swap_area.discard(req.rid)    # stale lazy-shed payload
            self.backend.release_table(table)
        self.budget.pop(slot, None)
        self.lengths[slot] = 0
        self.free.append(slot)
        if self.tel.enabled:
            tl = self.tel.timeline(req.rid)
            tl.preempt_ts.append(time.perf_counter())
            tl.outcome = "preempted"
            self.tel.recorder.record("preempt", tick=self._tick_no,
                                     rid=req.rid, slot=slot, swap=swap,
                                     swapped=swapped)
        span.args["swapped"] = swapped
        span.__exit__(None, None, None)
        return swapped

    def exec_swap_in(self, req: Request) -> Optional[int]:
        """Page a swapped sequence back in, or None if the pool cannot hold
        its block table right now.

        Pages kept live at swap-out (shared at the time) are reused as-is.
        Parked full-prompt pages first retry the prefix index — if an
        identical prefix is pooled (often our own parked copy, cached at
        release), the page revives with no upload; only genuine misses
        allocate a fresh page and upload the parked rows
        (swap_policy.plan_page_in, rollback on exhaustion)."""
        state = self.swap_area.peek(req.rid)
        park = state["park"]
        # conservative: lookups below can only reduce the real need
        if not self.backend.can_hold(park):
            return None
        extend = self.backend.page_in_extend(park)
        plan = swap_policy.plan_page_in(
            park, state["lookup_toks"], self.backend.page_size,
            lookup=lambda j, key: self.backend.lookup_prefix(j, key),
            extend=lambda j: extend(j),
            rollback=lambda j, pid: self.backend.decref_page(j, pid))
        if plan is None:           # defensive: entry stays put, retry later
            return None
        filled, upload = plan
        state = self.swap_area.take(req.rid)   # committed: pages acquired
        for j, pid in state["kept"]:
            filled[j] = pid
        slot = self.free.pop(0)
        try:
            with self.tel.tracer.span("swap_in", rid=req.rid, slot=slot,
                                      uploads=len(upload)):
                pages = [filled[j] for j in range(state["n_pages"])]
                if upload:
                    self.backend.upload_park(
                        state["rows"],
                        [(pos, park[pos], pid) for pos, pid in upload])
                if upload and state.get("register_prefix") \
                        and self.backend.share:
                    # transfer import: index uploaded full-prompt pages
                    # so later same-prefix imports COW-share them here
                    # instead of re-uploading (the plan's lookup already
                    # missed, so each registration is a fresh key)
                    self._register_imported(state, park, upload)
                self.tables[slot] = pages
                self.active[slot] = req
                pf = swap_policy.restore_progress(state)
                if pf is not None:
                    self._pf[slot] = pf
                    self.lengths[slot] = 0
                else:
                    self.lengths[slot] = state["length"]
                    self.backend.set_last_token(slot,
                                                state["last_token"])
                    self.budget[slot] = state["budget"]
        except Exception as err:
            # failed restore (e.g. corrupt payload at upload): the swap
            # entry is already consumed, so drop EVERY page the sequence
            # held — plan-acquired and kept alike — free the slot, and
            # let the scheduler fall back to recompute from the prompt
            # plus already-emitted tokens (exact under greedy decode)
            for j, pid in filled.items():
                self.backend.decref_page(j, pid)
            self.tables.pop(slot, None)
            self.active.pop(slot, None)
            self._pf.pop(slot, None)
            self.budget.pop(slot, None)
            self.lengths[slot] = 0
            self.free.append(slot)
            self._note_fault([], err, "swap_in")
            raise ExecFault([], err, "swap_in", rid=req.rid) from err
        if self.tel.enabled:
            tl = self.tel.timeline(req.rid)
            tl.resume_ts.append(time.perf_counter())
            tl.outcome = None                  # back in flight
            if upload:
                self.tel.metrics.counter(
                    "engine_pages_swapped_total",
                    "pages moved between pool and host").inc(
                    len(upload), dir="in", kind="resume")
                self.tel.metrics.counter(
                    "engine_swap_bytes_total",
                    "page bytes moved between pool and host").inc(
                    len(upload)
                    * getattr(self.backend, "page_bytes_full", 0),
                    dir="in", kind="resume")
            self.tel.recorder.record("swap_in", tick=self._tick_no,
                                     rid=req.rid, slot=slot,
                                     uploads=len(upload),
                                     kept=len(state["kept"]))
        return slot

    def _register_imported(self, state: dict, park, upload) -> None:
        """Prefix-index freshly uploaded full-prompt pages from a
        transfer payload. COW-shared prefixes therefore transfer once:
        the first import materializes and registers them; every later
        same-prefix import's page-in plan hits the index and shares the
        physical page with zero upload."""
        toks = state.get("lookup_toks")
        if not toks:
            return
        page = self.backend.page_size
        for pos, pid in upload:
            j = park[pos]
            end = (j + 1) * page
            if end <= len(toks):
                self.backend.register_prefix(j, tuple(toks[:end]), pid)

    # -- cross-instance transfer hooks (serving.disagg) ----------------------

    def export_request(self, rid: int
                       ) -> Optional[tuple[Request, Optional[dict]]]:
        """Detach a request from THIS instance for a cross-instance
        handoff; returns ``(req, payload)`` or None when ``rid`` is not
        in flight here.

        The payload is the backend-uniform flat swap format with every
        resident page gathered to the host — shared pages included:
        unlike a preemption, the request leaves this instance entirely,
        so no device reference may survive (``kept == []``) and the
        conservation invariant closes the moment this returns. Any
        lazy-shed payload merges in; per-page DLZS scores ride along
        when the backend can supply them. ``payload is None`` means the
        peer must recompute from prompt + emitted tokens (a waiting
        request that never started, or one preempted in recompute mode).
        """
        for slot, req in list(self.active.items()):
            if req.rid != rid:
                continue
            self.sched.drop_running_slot(slot)
            payload = self._export_slot(slot)
            self._note_export(req, payload)
            return req, payload
        for w in list(self.sched.waiting):
            if w.req.rid != rid:
                continue
            swapped = w.swapped
            req = self.sched.drop_waiting(rid)
            payload = self._export_parked(rid) if swapped else None
            if not swapped:
                self.swap_area.discard(rid)        # defensive
            self._note_export(req, payload)
            return req, payload
        return None

    def _export_slot(self, slot: int) -> dict:
        """Gather a bound slot's full state into a transfer payload and
        release everything it holds (mirrors ``exec_preempt``, except
        shared pages are gathered too — the peer's pool knows nothing of
        this pool's physical ids)."""
        req = self.active.pop(slot)
        table = self.tables.pop(slot)
        pf = self._pf.pop(slot, None)
        swap_policy.release_pending(
            pf, lambda pgs: self.backend.release_pages(pgs, len(table)))
        park = [j for j, pid in enumerate(table) if pid >= 0]
        shed = [j for j, pid in enumerate(table) if pid < 0]
        # gather BEFORE any decref: content is only guaranteed while
        # the pages hold at least one reference
        rows = self.backend.gather_park(table, park) if park else None
        state = swap_policy.progress_state(
            req, pf, share=self.backend.share,
            length=int(self.lengths[slot]),
            last_token=self.backend.get_last_token(slot),
            budget=self.budget.get(slot, 0))
        state.update(rows=rows, park=park, kept=[], n_pages=len(table))
        scorer = getattr(self.backend, "export_page_scores", None)
        scores = scorer(table, park) if scorer and park else None
        state = swap_policy.merge_shed(
            state, self.swap_area.discard(req.rid) if shed else None,
            concat_rows)
        if scores is not None:
            # shed pages were DLZS-cold when parked: score them 0 so the
            # advisory list still lines up with the merged park order
            state["scores"] = list(scores) + [0.0] * (
                len(state["park"]) - len(scores))
        state["register_prefix"] = bool(self.backend.share)
        self.backend.release_table(table)
        self.budget.pop(slot, None)
        self.lengths[slot] = 0
        self.free.append(slot)
        return state

    def _export_parked(self, rid: int) -> Optional[dict]:
        """Turn a fully-swapped sequence's payload into a transfer
        payload: ``kept`` pages (shared at preemption, still referenced
        on this pool) are gathered and their references dropped — the
        peer re-materializes them from rows like any parked page."""
        state = self.swap_area.discard(rid)
        if state is None:
            return None
        kept = list(state.get("kept", ()))
        if kept:
            synth = [-1] * state["n_pages"]
            for j, pid in kept:
                synth[j] = pid
            js = [j for j, _ in kept]
            kept_rows = self.backend.gather_park(synth, js)
            rows = kept_rows if state["rows"] is None \
                else concat_rows(state["rows"], kept_rows)
            for j, pid in kept:
                self.backend.decref_page(j, pid)
            state = dict(state, rows=rows,
                         park=list(state["park"]) + js, kept=[])
        else:
            state = dict(state, kept=[])
        state.pop("scores", None)
        state["register_prefix"] = bool(self.backend.share)
        return state

    def _note_export(self, req: Request,
                     payload: Optional[dict]) -> None:
        if not self.tel.enabled:
            return
        pages = len(payload["park"]) if payload else 0
        if pages:
            self.tel.metrics.counter(
                "engine_pages_swapped_total",
                "pages moved between pool and host").inc(
                pages, dir="out", kind="transfer")
        self.tel.recorder.record(
            "transfer_out", tick=self._tick_no, rid=req.rid,
            pages=pages, recompute=payload is None)

    def adopt(self, req: Request, payload: Optional[dict] = None) -> None:
        """Accept a request a peer instance exported.

        Unlike ``submit``, already-emitted tokens are PRESERVED. With a
        payload the request resumes exactly where it left off through
        the ordinary swap-in path: the payload parks in this instance's
        ``SwapArea`` and the scheduler admits it as a swapped waiting
        entry (``exec_swap_in`` re-allocates pages, uploads rows, and
        restores decode/prefill progress). Without one it replays
        prompt + emitted tokens through chunked prefill (exact under
        greedy decode) — the transfer-fault recompute fallback."""
        total = len(req.prompt) + req.max_tokens
        if req.max_len is not None:
            total = min(total, req.max_len)
        need = -(-total // self.backend.page_size)
        self.backend.check_capacity(req.rid, total, need)
        req.out = list(req.out or ())
        if req.submit_t is None:
            req.submit_t = time.perf_counter()
        if self.tel.enabled:
            self.tel.timeline(req.rid, sla=getattr(req, "sla", None))
            self.tel.recorder.record(
                "transfer_in", tick=self._tick_no, rid=req.rid,
                pages=len(payload["park"]) if payload else 0,
                recompute=payload is None)
        if payload is None:
            self.sched.submit(req)
            return
        assert not payload.get("kept"), \
            "transfer payloads must not carry device page ids"
        self.swap_area.put(req.rid, payload,
                           _rows_bytes(payload.get("rows")))
        self.sched.submit(req, swapped=True)

    # -- driver -------------------------------------------------------------

    def step(self) -> list[Request]:
        """One scheduler tick: admit / one-or-more prefill chunks / fused
        decode. Returns the requests that finished this step (normally or
        abnormally — check ``Request.finish_reason``). An exception that
        escapes the scheduler is ENGINE-level (per-request faults are
        contained inside the tick): the engine drains — every in-flight
        request fails terminally so no caller blocks forever — and then
        re-raises."""
        self._expire_deadlines()
        try:
            if not self.tel.enabled:
                fin = self.sched.tick(self)
            else:
                with self.tel.tracer.span("tick", n=self._tick_no):
                    fin = self.sched.tick(self)
        except Exception as e:
            self._drain(e)
            raise
        finally:
            self._tick_no += 1
        if self._terminal:
            fin = list(fin) + self._terminal
            self._terminal = []
        if self.tel.enabled:
            self._sync_metrics()
            if self.auditor.due(self._tick_no):
                self._run_audit()
        return fin

    def _drain(self, cause: BaseException) -> None:
        """Degraded-mode recovery from an engine-level failure: fail every
        in-flight and waiting request terminally (best effort — teardown
        errors are swallowed; the original ``cause`` is what propagates)
        so callers observe FAILED instead of hanging."""
        if self.tel.enabled:
            self.tel.recorder.record(
                "drain", tick=self._tick_no, error=repr(cause)[:200],
                n_active=len(self.active),
                n_waiting=len(self.sched.waiting))
            self.tel.metrics.counter(
                "engine_drains_total",
                "engine-level failures that drained all requests").inc()
        rids = [req.rid for req in self.active.values()]
        rids += [w.req.rid for w in self.sched.waiting]
        for rid in rids:
            try:
                self.cancel(rid, outcome="failed", reason="drain")
            except Exception:
                pass

    def _run_audit(self) -> None:
        """Sampled DLZS prediction audit: run the backend's exact-
        attention probe over one live decode sequence and fold the
        recall/score/skip-rate report (obs.audit). One extra decode-
        shaped dispatch per sample — never on the undecorated path."""
        slot = self.auditor.pick_slot(self._decode_slots())
        if slot is None:
            return
        rid = self.active[slot].rid
        with self.tel.tracer.span("audit", slot=slot, rid=rid):
            report = self.backend.audit_decode(
                slot, self.tables[slot], int(self.lengths[slot]))
        self.auditor.fold(report, self.tel.metrics, tick=self._tick_no,
                          rid=rid, recorder=self.tel.recorder)

    def _sync_metrics(self) -> None:
        """Fold scheduler stat deltas and pool occupancy into the
        registry (host-side state only; NO device syncs)."""
        reg = self.tel.metrics
        st = self.sched.stats
        for field in ("preemptions", "swap_outs", "recomputes",
                      "resumes", "sheds", "faults", "fault_retries",
                      "quarantines", "admission_sheds"):
            cur = getattr(st, field)
            delta = cur - self._sched_seen.get(field, 0)
            if delta > 0:
                reg.counter(f"engine_{field}_total",
                            f"scheduler {field}").inc(delta)
            self._sched_seen[field] = cur
        reg.counter("engine_ticks_total", "scheduler ticks").inc()
        bst = self.backend.stats()
        pool = bst.get("pool")
        if pool is not None:
            reg.gauge("engine_pool_pages_live",
                      "pool pages currently referenced").set(pool.live)
            reg.gauge("engine_pool_pages_capacity",
                      "pool page capacity").set(pool.capacity)
        pools = bst.get("pools")
        if isinstance(pools, dict) and "per_shard" in pools:
            for s, p in enumerate(pools["per_shard"]):
                live = p.live if hasattr(p, "live") else p["live"]
                cap = p.capacity if hasattr(p, "capacity") \
                    else p["capacity"]
                reg.gauge("engine_pool_pages_live",
                          "pool pages currently referenced").set(
                    live, shard=s)
                reg.gauge("engine_pool_pages_capacity",
                          "pool page capacity").set(cap, shard=s)
        if self.sched.budget_ctl is not None:
            reg.gauge("engine_prefill_budget_tokens",
                      "autotuned prefill token budget").set(
                self.sched.budget_ctl.budget)
        swap = self.swap_area.stats()
        reg.gauge("engine_swap_area_bytes",
                  "host bytes held by parked pages").set(swap.bytes)
        reg.gauge("engine_swap_area_entries",
                  "sequences parked on the host").set(swap.entries)

        # per-tick KV accounting + traffic deltas + the refcount watchdog
        snap = self.accounting_snapshot()
        fold_snapshot(reg, snap)
        q_events = snap["pool"].get("quantize_events", 0)
        dq = q_events - self._quant_seen
        if dq > 0:
            fold_traffic(reg, quantized_pages=dq,
                         page_bytes_int8=getattr(
                             self.backend, "page_bytes_int8", 0))
            self.tel.recorder.record("quant", tick=self._tick_no,
                                     pages=dq)
        self._quant_seen = q_events
        wd = reconcile_refs(self._expected_refs(),
                            self.backend.pool_refs())
        if not wd.ok:
            reg.counter(
                "engine_watchdog_violations_total",
                "pool refcounts the engine's tables and swap area "
                "cannot explain (leak / double-free in waiting)").inc(
                wd.violations)
            self.tel.recorder.record("watchdog", tick=self._tick_no,
                                     violations=wd.violations,
                                     detail=wd.describe()[:400])

    def dlzs_hot_fraction(self) -> Optional[float]:
        """Fraction of decode-phase live pages inside the DLZS hot set —
        a point-in-time snapshot for metrics() / the exposition endpoint.
        Pulls page scores from the device, so NEVER call per tick."""
        live = 0
        hot_n = 0
        for slot in self._decode_slots():
            table = self.tables.get(slot)
            if not table:
                continue
            hot = self.backend.hot_logical(table)
            for j, pid in enumerate(table):
                if pid is None or pid < 0:     # SHED sentinel
                    continue
                live += 1
                if j in hot:
                    hot_n += 1
        return round(hot_n / live, 4) if live else None

    def run(self, requests: list[Request], max_steps: int = 10_000):
        """Serve a request list to completion; returns {rid: tokens}."""
        for r in requests:
            self.submit(r)
        done: dict[int, list] = {}
        steps = 0
        while self.sched.has_work() and steps < max_steps:
            for fin in self.step():
                done[fin.rid] = fin.out
            steps += 1
        return done

    # -- observability ------------------------------------------------------

    def accounting_snapshot(self) -> dict:
        """One tick's page-accounting census, from host state only.

        Every page the engine has allocated for a sequence is classified
        into exactly one of: **hot** (in the last decode step's bounded
        hot-set), **cold** (resident but not gathered), **shed** (SHED
        sentinel — content parked host-side while the sequence keeps
        decoding), or **swapped** (the whole sequence is parked), so
        ``allocated == hot + cold + shed + swapped`` holds at every tick
        boundary (obs.accounting.conservation_error). Pages of slots
        still mid-prefill (and decode slots the last decode step did not
        cover) count as cold. Fragmentation is the decode slots' tail
        slack: allocated-but-unwritten token positions over resident
        token capacity. No device syncs — block tables, the swap area
        and the backend's pool census are all host-side."""
        page = self.backend.page_size
        sparsity = getattr(self.backend, "decode_sparsity", None) or {}
        per_slot = sparsity.get("per_slot") or {}
        decoding = set(self._decode_slots())
        resident = shed = hot = 0
        token_slack = token_capacity = 0
        for slot, table in self.tables.items():
            res_slot = sum(1 for pid in table if pid >= 0)
            shed_slot = len(table) - res_slot
            resident += res_slot
            shed += shed_slot
            if slot in decoding:
                _, n_hot = per_slot.get(slot, (res_slot, 0))
                hot += min(n_hot, res_slot)
                on_device = int(self.lengths[slot]) - shed_slot * page
                token_capacity += res_slot * page
                token_slack += max(res_slot * page - on_device, 0)
        active_rids = {req.rid for req in self.active.values()}
        swapped = 0
        for rid, payload in self.swap_area.items():
            if rid in active_rids:
                continue   # lazy-shed payload: its pages ARE the shed
                #            sentinels above — counting both double-books
            swapped += payload.get("n_pages",
                                   len(payload.get("park", ())))
        return {
            "tick": self._tick_no,
            "pages": {"allocated": resident + shed + swapped,
                      "resident": resident, "hot": hot,
                      "cold": resident - hot, "shed": shed,
                      "swapped": swapped},
            "fragmentation": {
                "token_slack": token_slack,
                "token_capacity": token_capacity,
                "frac": round(token_slack / token_capacity, 6)
                if token_capacity else 0.0},
            "pool": self.backend.page_accounting(),
            "bytes": {
                "per_page_full": getattr(self.backend,
                                         "page_bytes_full", 0),
                "per_page_gather": getattr(self.backend,
                                           "page_bytes_gather", 0),
                "per_page_int8": getattr(self.backend,
                                         "page_bytes_int8", 0)},
        }

    def _expected_refs(self) -> dict:
        """(shard, pid) -> refcount the engine's state implies: one ref
        per live block-table entry plus one per swap-payload ``kept``
        entry (shared pages a fully-parked sequence still holds)."""
        expected: dict[tuple[int, int], int] = {}
        for table in self.tables.values():
            for j, pid in enumerate(table):
                if pid < 0:
                    continue
                key = (self.backend.owner_of(j), pid)
                expected[key] = expected.get(key, 0) + 1
        active_rids = {req.rid for req in self.active.values()}
        for rid, payload in self.swap_area.items():
            if rid in active_rids:
                continue               # lazy-shed payloads hold no refs
            for j, pid in payload.get("kept", ()):
                key = (self.backend.owner_of(j), pid)
                expected[key] = expected.get(key, 0) + 1
        return expected

    def stats(self) -> dict:
        st = self.backend.stats()
        st["swap"] = self.swap_area.stats()
        st["sched"] = dataclasses.replace(self.sched.stats)
        return st
