"""Subprocess program: decode-time DLZS sparsity parity on an N-shard
fake-device mesh (tests/test_decode_sparse.py drives it; the parent's
XLA device count is fixed at first jax init, hence the subprocess).

The spatial leg of the parity matrix:

* ``decode_hot_width=None`` + quant off — token-identical to the dense
  oracle (the sparse plumbing must be invisible);
* bounded per-shard width — first token exact (prefill is
  width-independent), greedy top-1 agreement above a floor, exactly one
  decode compile, and the pages-skipped telemetry populated;
* ``kv_quant="int8"`` at the minimal width — hot = {newest local, sink
  local} per shard is never quantized and is all the gather reads, so
  tokens must be identical to the same width without the tier while
  cold pages demonstrably quantize.

argv[1] = shard count. Prints DECODE_SPARSE_OK on success.
"""

import os
import sys

N_SHARDS = int(sys.argv[1]) if len(sys.argv) > 1 else 2
os.environ["XLA_FLAGS"] = \
    f"--xla_force_host_platform_device_count={N_SHARDS}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving import (EngineCfg, LLM, SchedulerCfg, ServingEngine)
from repro.spatial import SpatialEngineCfg, SpatialServingEngine

LENGTHS = (5, 21, 40, 64)
GEN = 24

cfg = dataclasses.replace(get_smoke_config("olmo_1b"), star=None)
params = lm.init(jax.random.PRNGKey(1), cfg)
prompts = [(np.arange(l, dtype=np.int32) * 7 + i) % cfg.vocab
           for i, l in enumerate(LENGTHS)]


def run(llm):
    handles = [llm.submit(p, max_tokens=GEN, rid=i)
               for i, p in enumerate(prompts)]
    done = llm.run_until_done(max_steps=10_000)
    assert all(h.done for h in handles)
    return done


def spatial(width=None, kv_quant=None):
    scfg = SchedulerCfg(chunk_pages=1, decode_hot_width=width,
                        kv_quant=kv_quant)
    return LLM(SpatialServingEngine(cfg, params, SpatialEngineCfg(
        n_shards=N_SHARDS, max_batch=2, page_size=16, n_pages_local=24,
        hot_pages_local=8, recent_pages=2, eos_id=-1), scfg))


def agreement(got, want):
    fr = []
    for rid in want:
        n = 0
        for x, y in zip(got[rid], want[rid]):
            if x != y:
                break
            n += 1
        fr.append(n / max(len(want[rid]), 1))
    return sum(fr) / len(fr)


want = run(LLM(ServingEngine(cfg, params,
                             EngineCfg(max_batch=2, max_len=128,
                                       eos_id=-1))))

# 1. width=None: bit-identical to the dense oracle
llm = spatial()
got = run(llm)
assert got == want, f"width=None changed tokens:\n{got}\n{want}"
assert llm.stats()["decode_compiles"] == 1
print(f"[{N_SHARDS} shards] width=None: exact")

# 2. bounded per-shard width: first-token exactness + agreement floor
llm = spatial(width=2)
got = run(llm)
for rid in want:
    assert got[rid][0] == want[rid][0], f"rid {rid} first token"
agr = agreement(got, want)
assert agr >= 0.5, f"width=2 agreement {agr:.3f} < 0.5"
st = llm.stats()
assert st["decode_compiles"] == 1
assert st["hot_width"] == 2
spars = llm.engine.backend.decode_sparsity
assert spars is not None and spars["pages_hot"] <= spars["pages_total"]
print(f"[{N_SHARDS} shards] width=2: agreement {agr:.3f}")

# 3. int8 tier at minimal width: token-exact, cold pages quantized
base = run(spatial(width=2))
llm = spatial(width=2, kv_quant="int8")
got = run(llm)
assert got == base, "unread int8 tier perturbed the fp gather"
kq = llm.stats()["kv_quant"]
assert kq["quantize_events"] > 0, "no cold page ever quantized"
assert kq["bytes_per_page_int8"] < kq["bytes_per_page_fp"]
print(f"[{N_SHARDS} shards] width=2+int8: exact, "
      f"{kq['quantize_events']} quantize events")

print("DECODE_SPARSE_OK")
