# The paper's primary contribution — the STAR cross-stage sparse-attention
# pipeline (DLZS prediction, SADS selection, SU-FA formal compute) plus the
# spatial-architecture layer (DRAttention dataflow, MRCA schedule).

from repro.core.dlzs import (dlzs_scores, lz_pack, lz_unpack, pow2_quantize,
                             predict_khat, slzs_scores)
from repro.core.sads import (BlockSelection, SADSSelection, gather_blocks,
                             gather_selected, sads_select, sads_select_blocks)
from repro.core.star_attention import (STARConfig, dense_attention,
                                       star_attention,
                                       star_attention_batched, star_decode)
from repro.core.sufa import masked_attention_ref, sufa_gathered, sufa_scan

__all__ = [
    "BlockSelection", "SADSSelection", "STARConfig", "dense_attention",
    "dlzs_scores", "gather_blocks", "gather_selected", "lz_pack", "lz_unpack",
    "masked_attention_ref", "pow2_quantize", "predict_khat", "sads_select",
    "sads_select_blocks", "slzs_scores", "star_attention",
    "star_attention_batched", "star_decode", "sufa_gathered", "sufa_scan",
]
