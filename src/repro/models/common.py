"""Shared layer primitives: norms, activations, RoPE, initializers.

Every layer exposes the triplet
    init(key, cfg)  -> params (nested dict of arrays)
    apply(params, x, ...) -> y
    axes(cfg)       -> same-structure tree of logical-axis tuples
so the launch layer can derive shardings without instantiating weights
(dry-run uses jax.eval_shape over init).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale, dtype=jnp.float32):
    """He/LeCun-style fan-in init used across the framework."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = (scale / max(1, fan_in)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32, parametric: bool = True):
    if not parametric:   # OLMo's non-parametric LN
        return {}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if "scale" in params:
        y = y * params["scale"].astype(jnp.float32) \
            + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_init(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return rmsnorm_init(d, dtype)
    if kind == "layernorm":
        return layernorm_init(d, dtype, parametric=True)
    if kind == "nonparametric_ln":
        return layernorm_init(d, dtype, parametric=False)
    raise ValueError(f"unknown norm {kind}")


def norm_apply(kind: str, params, x):
    if kind == "rmsnorm":
        return rmsnorm_apply(params, x)
    return layernorm_apply(params, x)


def norm_axes(kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ("embed",)}
    if kind == "layernorm":
        return {"scale": ("embed",), "bias": ("embed",)}
    return {}


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(kind: str):
    if kind == "gelu":
        return jax.nn.gelu
    if kind == "silu":
        return jax.nn.silu
    if kind == "relu":
        return jax.nn.relu
    if kind == "relu2":  # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {kind}")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, rotary_dim: int, theta: float):
    exponents = jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim
    return 1.0 / (theta ** exponents)  # [rotary_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 1e4,
               rotary_fraction: float = 1.0) -> jax.Array:
    """Rotate pairs (x[2i], x[2i+1]) of the first ``rotary_fraction`` of dims.

    x: [..., S, n_heads, head_dim]; positions: broadcastable to [..., S].
    ``rotary_fraction=0.5`` gives ChatGLM's 2-d RoPE (rotary on half the
    head dim, identity on the rest).
    """
    head_dim = x.shape[-1]
    rotary_dim = int(head_dim * rotary_fraction)
    rotary_dim -= rotary_dim % 2
    if rotary_dim == 0:
        return x
    freqs = rope_frequencies(head_dim, rotary_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [...,S,rd/2]
    cos = jnp.cos(angles)[..., :, None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    xr = x[..., :rotary_dim].astype(jnp.float32)
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate(
        [rotated.astype(x.dtype), x[..., rotary_dim:]], axis=-1)
