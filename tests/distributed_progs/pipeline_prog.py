"""Subprocess program: GPipe over 4 stages == sequential composition."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.pipeline import bubble_fraction, gpipe

mesh = jax.make_mesh((4,), ("stage",))
n_stages, n_micro, b, d = 4, 6, 2, 8
ks = jax.random.split(jax.random.PRNGKey(0), 2)
params = {"w": jax.random.normal(ks[0], (n_stages, d, d)) * 0.3,
          "b": jnp.zeros((n_stages, d))}
x = jax.random.normal(ks[1], (n_micro, b, d))


def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])


out = jax.jit(lambda p, x: gpipe(stage_fn, p, x, mesh=mesh, axis="stage")
              )(params, x)

# sequential reference
ref = x
for s in range(n_stages):
    ref = stage_fn(jax.tree.map(lambda t: t[s], params), ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
assert abs(bubble_fraction(4, 6) - 3 / 9) < 1e-9
print("ALL_OK")
