"""Subprocess program: the shared backend-conformance scenarios
(tests/engine_core_scenarios.py) against SpatialServingEngine on N fake
devices — the same suite the paged backend passes in-process, driven
through the ``LLM`` front door. Includes the shed-under-pressure
scenario: with ``lazy_swap`` the sharded pools must shed DLZS-cold
ref-1 pages (via the shared EngineCore path) without full preemption.

argv[1] = shard count; argv[2] = scenario set ("all" — the default
tier-1 conformance run — or "chaos" for the fault-injection/lifecycle
scenarios the CI chaos job drives). Prints CONFORMANCE_OK on success.
"""

import os
import sys

N_SHARDS = int(sys.argv[1]) if len(sys.argv) > 1 else 2
MODE = sys.argv[2] if len(sys.argv) > 2 else "all"
os.environ["XLA_FLAGS"] = \
    f"--xla_force_host_platform_device_count={N_SHARDS}"
_HERE = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_HERE, ".."))               # scenarios
sys.path.insert(0, os.path.join(_HERE, "..", "..", "src"))

import dataclasses

import jax

import engine_core_scenarios as scen
from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving import LLM
from repro.spatial import SpatialEngineCfg, SpatialServingEngine

cfg = dataclasses.replace(get_smoke_config("olmo_1b"), star=None)
params = lm.init(jax.random.PRNGKey(1), cfg)


def make_llm(*, max_batch, pages, hot, scfg, recent=2):
    return LLM(SpatialServingEngine(cfg, params, SpatialEngineCfg(
        n_shards=N_SHARDS, max_batch=max_batch, page_size=16,
        n_pages_local=pages, hot_pages_local=hot, recent_pages=recent,
        eos_id=-1), scfg))


bp = scen.BACKEND_PARAMS[f"spatial{N_SHARDS}"]
runner = scen.run_chaos if MODE == "chaos" else scen.run_all
runner(make_llm, cfg, params, bp,
       log=lambda m: print(f"[{N_SHARDS} shards] {m}"))
print("CONFORMANCE_OK")
