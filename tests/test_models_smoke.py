"""Per-arch smoke tests: reduced same-family configs, one forward/train step
on CPU, asserting output shapes + finiteness (spec deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.launch import shapes as shp
from repro.models import lm

jax.config.update("jax_enable_x64", False)

SMOKE_ARCHS = [a for a in ARCHS if a != "star_paper"]


def _batch(cfg, b=2, s=64, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {}
    if cfg.enc_layers:
        batch["enc_embeds"] = jax.random.normal(
            key, (b, s, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    elif cfg.embeds_input:
        batch["embeds"] = jax.random.normal(
            key, (b, s, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(
        jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: lm.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    assert float(loss) > 0
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_grads_finite(arch):
    cfg = get_smoke_config(arch)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    grads = jax.jit(jax.grad(
        lambda p, b: lm.loss_fn(p, cfg, b)[0]))(params, batch)
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all(), \
            f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 64
    batch = _batch(cfg)
    batch.pop("labels")
    logits, cache = jax.jit(
        lambda p, bt: lm.prefill(p, cfg, bt, cache_len=s + 8))(params, batch)
    assert logits.shape == (b, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))
    for _ in range(3):
        logits2, cache = step(params, tok, cache)
        assert logits2.shape == (b, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits2, np.float32)).all()
        tok = jnp.argmax(logits2, -1)[:, None].astype(jnp.int32)


def test_shape_applicability_rules():
    long = shp.SHAPES["long_500k"]
    from repro.configs import get_config
    assert shp.applicability(get_config("xlstm_125m"), long) is None
    assert shp.applicability(get_config("jamba_1_5_large_398b"), long) is None
    assert shp.applicability(get_config("chatglm3_6b"), long) is not None
    assert shp.applicability(get_config("chatglm3_6b"), long,
                             allow_star_long=True) is None
    for name in ("train_4k", "prefill_32k", "decode_32k"):
        assert shp.applicability(get_config("nemotron_4_340b"),
                                 shp.SHAPES[name]) is None


def test_decode_matches_forward_dense():
    """Greedy decode from the cache must match teacher-forced forward logits
    for a dense arch (cache correctness)."""
    cfg = get_smoke_config("olmo_1b")
    import dataclasses
    cfg = dataclasses.replace(cfg, star=None)  # exact attention
    params = lm.init(jax.random.PRNGKey(1), cfg)
    b, s = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0,
                                cfg.vocab)
    # teacher-forced logits at position s (predicting token s+1)
    logits_all, cache_full = lm.prefill(params, cfg,
                                        {"tokens": tokens}, cache_len=s + 4)
    # prefill on the first s tokens, then decode token s
    logits_pre, cache = lm.prefill(params, cfg,
                                   {"tokens": tokens[:, :s]},
                                   cache_len=s + 4)
    logits_dec, _ = lm.decode_step(params, cfg, tokens[:, s:s + 1], cache)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_all, np.float32), rtol=0.05, atol=0.05)
