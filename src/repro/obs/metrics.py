"""Serving metrics: counters / gauges / histograms with label sets.

A ``MetricsRegistry`` owns named metrics; each metric holds one series
per label-set (``tuple(sorted(labels.items()))`` key), so per-SLA and
per-shard breakdowns are just labels on the same counter. Everything is
plain host-side Python — incrementing a counter is a dict lookup and an
add — and the registry renders a Prometheus-style text exposition for
``launch/serve.py --metrics``.
"""

from __future__ import annotations

from typing import Optional


def _key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_str(key: tuple) -> str:
    return ",".join(f'{k}="{v}"' for k, v in key)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.series: dict[tuple, float] = {}

    def value(self, **labels) -> float:
        return self.series.get(_key(labels), 0.0)

    def snapshot(self):
        """Scalar for a single unlabeled series, else {label_str: value}."""
        if len(self.series) == 1 and () in self.series:
            return self.series[()]
        return {_label_str(k) or "": v for k, v in sorted(self.series.items())}

    def render(self) -> list[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        for key, val in sorted(self.series.items()):
            label = "{" + _label_str(key) + "}" if key else ""
            lines.append(f"{self.name}{label} {val:g}")
        return lines


class Counter(_Metric):
    """Monotonically non-decreasing; ``inc`` with a negative amount raises."""

    kind = "counter"

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        key = _key(labels)
        self.series[key] = self.series.get(key, 0.0) + amount


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self.series[_key(labels)] = float(value)


# seconds-scale buckets: 1ms .. 10s covers tick phases through requests
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets=DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(buckets)
        # per label-set: {"counts": [..per bucket.. , +Inf], "sum", "count"}
        self.series: dict[tuple, dict] = {}

    def observe(self, value: float, **labels) -> None:
        key = _key(labels)
        s = self.series.get(key)
        if s is None:
            s = {"counts": [0] * (len(self.buckets) + 1),
                 "sum": 0.0, "count": 0}
            self.series[key] = s
        for i, b in enumerate(self.buckets):
            if value <= b:
                s["counts"][i] += 1
                break
        else:
            s["counts"][-1] += 1
        s["sum"] += value
        s["count"] += 1

    def value(self, **labels):
        s = self.series.get(_key(labels))
        return None if s is None else dict(s)

    def snapshot(self):
        out = {}
        for key, s in sorted(self.series.items()):
            out[_label_str(key) or ""] = {
                "count": s["count"],
                "sum": round(s["sum"], 6),
                "mean": round(s["sum"] / s["count"], 6) if s["count"] else 0.0,
            }
        if len(out) == 1 and "" in out:
            return out[""]
        return out

    def render(self) -> list[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} histogram")
        for key, s in sorted(self.series.items()):
            base = _label_str(key)
            cum = 0
            for b, c in zip(self.buckets, s["counts"]):
                cum += c
                le = f'le="{b:g}"'
                label = "{" + (base + "," if base else "") + le + "}"
                lines.append(f"{self.name}_bucket{label} {cum}")
            cum += s["counts"][-1]
            label = "{" + (base + "," if base else "") + 'le="+Inf"' + "}"
            lines.append(f"{self.name}_bucket{label} {cum}")
            suffix = "{" + base + "}" if base else ""
            lines.append(f"{self.name}_sum{suffix} {s['sum']:g}")
            lines.append(f"{self.name}_count{suffix} {s['count']}")
        return lines


class MetricsRegistry:
    """Get-or-create registry; re-registering a name with a different
    metric type is an error (a silent type change would corrupt series)."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def snapshot(self) -> dict:
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def render_prometheus(self) -> str:
        lines = []
        for _, m in sorted(self._metrics.items()):
            lines.extend(m.render())
        return "\n".join(lines) + "\n" if lines else ""
