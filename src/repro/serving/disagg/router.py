"""DisaggRouter: the prefill/decode-disaggregated serving front door.

Serving mixes two phase profiles that want opposite tunings: prefill is
compute-bound and batches wide token budgets; decode is memory-bound
and wants a big batch over a deep pool with a narrow DLZS hot set. A
single instance compromises both — and long prefills stall co-resident
decodes behind the shared dispatch. ``DisaggRouter`` runs two engine
instances instead and moves each request across at the phase boundary:

    submit ──▶ prefill instance (large ``prefill_tokens`` budget)
                  │  first token emitted (prefill complete)
                  ▼
               KVTransfer.begin/complete  (flat-payload page handoff)
                  │
                  ▼
               decode instance (big ``max_batch``, deep pool,
               ``decode_hot_width`` sparsity) ──▶ finished

It IS an ``LLM`` — same ``submit()/tick()/metrics()/debug_bundle()``
surface — overriding only the three engine touch-points the base class
exposes (``_submit_engine``/``_step_engines``/``_cancel_engine``). One
``obs.Telemetry`` is shared by both instances, so a request has a
single timeline stamped across its whole journey (admit on the prefill
side, ``transfer_out``/``transfer_in`` at the hop, per-token stamps on
the decode side).

Handoff state machine (per request)::

    PREFILLING ──prefill done──▶ ELIGIBLE ──begin──▶ STAGED
       │                            │                  │ complete
       │ preempted to decode-kind   │ export fault     ▼
       │ payload / recompute mode   ▼                LANDED (decode)
       └──────▶ ELIGIBLE         RECOMPUTE ──adopt(None)──▶ decode
                                    │ retries exhausted
                                    └──▶ FAILED (terminal)

Eligibility is checked after every prefill tick: a bound slot past its
prefill (``slot not in _pf``), a swapped waiting entry whose parked
payload is decode-kind, or a recompute-mode waiting entry that already
emitted tokens. Requests still mid-prefill — including those preempted
with prefill-kind payloads — stay on the prefill instance.

Conservation holds across BOTH pools plus the fabric every tick:
export closes the source side (no ``kept`` refs travel), staged
payloads hold host bytes only, and adopt re-enters the destination
through the audited swap-in path. A transfer fault therefore loses
bytes, never pages: the retained request replays prompt + emitted
tokens through decode-side chunked prefill (exact under greedy
decode), gated by a ``RetryGovernor``.
"""

from __future__ import annotations

from typing import Optional

from repro.serving.api import LLM
from repro.serving.disagg.transfer import KVTransfer
from repro.serving.engine import Request
from repro.serving.swap_policy import RetryGovernor


class DisaggRouter(LLM):
    """Front door over a (prefill, decode) instance pair.

    ``prefill_engine``/``decode_engine`` are ``EngineCore`` instances
    (any swap-format backend; they need not match — spatial prefill
    into paged decode works). ``fault_plan`` injects at the
    ``transfer`` seam; ``staging`` picks the fabric mode (see
    ``KVTransfer``). The decode instance is ``self.engine`` — the base
    class serves records, metrics and bundles from it."""

    def __init__(self, prefill_engine, decode_engine, *, telemetry=None,
                 fault_plan=None, staging: str = "device",
                 transfer_retries: int = 2):
        super().__init__(decode_engine, telemetry=telemetry)
        self.prefill = prefill_engine
        # one telemetry identity across both instances: the engines
        # stamp the SAME timeline objects the router's records wrap
        if hasattr(prefill_engine, "attach_telemetry"):
            prefill_engine.attach_telemetry(self.tel)
        self.transfer = KVTransfer(prefill_engine, decode_engine,
                                   plan=fault_plan, telemetry=self.tel,
                                   staging=staging)
        self.governor = RetryGovernor(max_retries=transfer_retries)

    # -- construction --------------------------------------------------------

    @classmethod
    def from_config(cls, model_cfg, *, backend: str = "paged",
                    prefill_backend: Optional[str] = None,
                    params=None, shards: int = 2,
                    prefill_engine_cfg=None, decode_engine_cfg=None,
                    prefill_sched_cfg=None, decode_sched_cfg=None,
                    rng=None, telemetry=None, fault_plan=None,
                    staging: str = "device") -> "DisaggRouter":
        """Build the instance pair around ONE set of params.

        ``backend`` picks the decode instance ("paged" or "spatial");
        ``prefill_backend`` the prefill side (default: same as
        ``backend``). Default tunings encode the disaggregation split:
        the prefill instance runs a small batch with the "auto" prefill
        token budget; the decode instance runs the full batch with
        decode-width sparsity and no prefill budget (its only prefills
        are recompute fallbacks)."""
        import jax

        from repro.models import lm
        from repro.serving.paged import PagedEngineCfg, PagedServingEngine
        from repro.serving.scheduler import SchedulerCfg

        rng = rng if rng is not None else jax.random.PRNGKey(0)
        if params is None:
            params = lm.init(rng, model_cfg)

        def build(kind, engine_cfg, sched_cfg):
            if kind == "paged":
                return PagedServingEngine(
                    model_cfg, params, engine_cfg or PagedEngineCfg(),
                    sched_cfg, rng=rng)
            if kind == "spatial":
                from repro.spatial.engine import (SpatialEngineCfg,
                                                  SpatialServingEngine)
                return SpatialServingEngine(
                    model_cfg, params,
                    engine_cfg or SpatialEngineCfg(n_shards=shards),
                    sched_cfg, rng=rng)
            raise ValueError(f"unknown disagg backend {kind!r}: "
                             "choose from ('paged', 'spatial')")

        pre = build(prefill_backend or backend, prefill_engine_cfg,
                    prefill_sched_cfg
                    or SchedulerCfg(prefill_tokens="auto"))
        dec = build(backend, decode_engine_cfg,
                    decode_sched_cfg or SchedulerCfg())
        return cls(pre, dec, telemetry=telemetry, fault_plan=fault_plan,
                   staging=staging)

    # -- the LLM engine seam -------------------------------------------------

    def _submit_engine(self, req: Request) -> None:
        self.prefill.submit(req)

    def _cancel_engine(self, rid: int, *, reason: str) -> bool:
        if self.prefill.cancel(rid, reason=reason):
            return True
        req = self.transfer.drop(rid)
        if req is not None:
            # mid-hop: no pages are held anywhere — stamp terminal on
            # the decode side so the finished stream surfaces it
            self.engine.exec_abort(req, "cancelled", reason)
            return True
        return self.engine.cancel(rid, reason=reason)

    def _step_engines(self) -> list[Request]:
        finished = list(self.prefill.step() or ())
        for rid in self._handoff_candidates():
            self._handoff(rid)
        finished += self.engine.step() or []
        return finished

    # -- handoff -------------------------------------------------------------

    def _handoff_candidates(self) -> list[int]:
        """Requests done with prefill on the prefill instance: decoding
        in a slot, parked with a decode-kind payload, or waiting in
        recompute mode with tokens already emitted."""
        pre = self.prefill
        rids = [req.rid for slot, req in pre.active.items()
                if slot not in pre._pf]
        for w in pre.sched.waiting:
            if w.swapped:
                payload = pre.swap_area.peek(w.req.rid)
                if payload is not None and payload.get("kind") == "decode":
                    rids.append(w.req.rid)
            elif w.req.out:
                rids.append(w.req.rid)
        return rids

    def _handoff(self, rid: int) -> None:
        try:
            summary = self.transfer.begin(rid)
        except Exception:
            req = self.transfer.drop(rid)
            if req is None:
                return
            # the payload is gone; the only retry is a decode-side
            # recompute replay (backoff is meaningless for a one-way
            # hop, so the governor only gates the attempt count)
            if self.governor.record_fault(rid) is None:
                self.engine.exec_abort(req, "failed", "transfer")
            else:
                self.engine.adopt(req)
            return
        if summary is None:     # finished/cancelled under our feet
            return
        self.transfer.complete(rid)
        self.governor.forget(rid)

    # -- surface -------------------------------------------------------------

    def has_work(self) -> bool:
        pre = self.prefill
        return bool(pre.queue or pre.active
                    or getattr(pre, "_terminal", ())
                    or self.transfer.in_flight()
                    or super().has_work())

    def stats(self) -> dict:
        # decode-side pool/sched stay top-level: base-class metrics()
        # reads occupancy and preemptions from there
        st = self.engine.stats()
        st["prefill"] = self.prefill.stats()
        st["transfer"] = self.transfer.stats()
        return st

    def debug_bundle(self, out_dir: Optional[str] = None) -> str:
        import json
        import os

        out = super().debug_bundle(out_dir)
        if hasattr(self.prefill, "accounting_snapshot"):
            with open(os.path.join(out, "accounting_prefill.json"),
                      "w") as f:
                json.dump(self.prefill.accounting_snapshot(), f,
                          indent=2, default=repr)
                f.write("\n")
        with open(os.path.join(out, "transfer.json"), "w") as f:
            json.dump(self.transfer.stats(), f, indent=2, default=repr)
            f.write("\n")
        return out
