"""Equivalent-addition complexity model (paper footnote 1 + Figs. 5/16/18).

C = α·N_add + β·N_mul + γ·N_cmp + δ·N_div + ε·N_exp with
α=1, β=3, γ=1, δ=8, ε=25 (Brent & Zimmermann [15]). Every benchmark that
reproduces a paper complexity figure goes through this module so the weights
live in exactly one place.
"""

from __future__ import annotations

import dataclasses

ALPHA, BETA, GAMMA, DELTA, EPSILON = 1.0, 3.0, 1.0, 8.0, 25.0


@dataclasses.dataclass(frozen=True)
class OpCount:
    add: float = 0.0
    mul: float = 0.0
    cmp: float = 0.0
    div: float = 0.0
    exp: float = 0.0

    def __add__(self, other: "OpCount") -> "OpCount":
        return OpCount(*(getattr(self, f.name) + getattr(other, f.name)
                         for f in dataclasses.fields(self)))

    def scaled(self, c: float) -> "OpCount":
        return OpCount(*(c * getattr(self, f.name)
                         for f in dataclasses.fields(self)))

    @property
    def equivalent_adds(self) -> float:
        return (ALPHA * self.add + BETA * self.mul + GAMMA * self.cmp
                + DELTA * self.div + EPSILON * self.exp)


def matmul_ops(m: int, n: int, k: int) -> OpCount:
    """[m,k] @ [k,n]."""
    return OpCount(mul=m * n * k, add=m * n * (k - 1))


def shift_matmul_ops(m: int, n: int, k: int) -> OpCount:
    """DLZS 'matmul': shifts are free in the ASIC model — adds only."""
    return OpCount(add=m * n * k)


def vanilla_attention_ops(t: int, s: int, d: int) -> OpCount:
    """Dense attention, monolithic softmax (no tiling): QKᵀ, softmax, AV."""
    ops = matmul_ops(t, s, d)                       # QK^T
    ops += OpCount(cmp=t * (s - 1))                 # rowmax
    ops += OpCount(add=t * s, exp=t * s)            # subtract max, exp
    ops += OpCount(add=t * (s - 1), div=t * s)      # rowsum, normalize
    ops += matmul_ops(t, d, s)                      # A·V
    return ops


def fa2_ops(t: int, s: int, d: int, block_kv: int) -> OpCount:
    """FlashAttention-2 (Fig. 5a): per KV tile — rowmax over Bc, max-merge,
    exp(Bc) + correction exp, l rescale (1 mul), o rescale (d mul)."""
    n_tiles = s // block_kv
    ops = matmul_ops(t, s, d) + matmul_ops(t, d, s)  # same matmul work
    per_tile_row = OpCount(
        cmp=(block_kv - 1) + 1,       # rowmax(S_ij) + m' = max(m, ·)
        exp=block_kv + 1,             # exp(S_ij - m') + correction e^{m-m'}
        add=block_kv + (block_kv - 1) + 1,  # subtract m', rowsum, l merge
        mul=1 + d,                    # l rescale + o rescale
    )
    ops += per_tile_row.scaled(t * n_tiles)
    ops += OpCount(div=t * d)         # final o / l
    return ops


def sufa_ops(t: int, s: int, d: int, block_kv: int, keep_ratio: float,
             strict: bool = False) -> OpCount:
    """SU-FA over the selected tiles only (keep_ratio of tiles survive SADS).

    Descend updating (strict=False): no max comparisons against the running
    max and no o/l rescale multiplies after tile 0 (Fig. 11b).
    """
    n_tiles = max(1, round((s // block_kv) * keep_ratio))
    s_eff = n_tiles * block_kv
    ops = matmul_ops(t, s_eff, d) + matmul_ops(t, d, s_eff)
    per_tile_row = OpCount(
        cmp=(block_kv - 1) + (1 if strict else 0),
        exp=block_kv + (1 if strict else 0),
        add=block_kv + (block_kv - 1) + 1,
        mul=(1 + d) if strict else 0,
    )
    ops += per_tile_row.scaled(t * n_tiles)
    ops += OpCount(div=t * d)
    return ops


def full_sort_topk_ops(t: int, s: int, k_ratio: float) -> OpCount:
    """Row-wide selection of S·k entries, O(S) per selected entry (paper §III)."""
    k = s * k_ratio
    return OpCount(cmp=t * s * k)


def sads_ops(t: int, s: int, k_ratio: float, n_segments: int,
             rho: float) -> OpCount:
    """SADS: per segment, find max (S/n cmp), sphere filter (S/n cmp), then
    top-(k/n) over the surviving rho fraction: O((S/n)·rho·(k/n)) per segment.
    Total O(S·S·k·rho/n) per row (paper's complexity claim)."""
    seg = s // n_segments
    k_seg = (s * k_ratio) / n_segments
    per_seg = OpCount(cmp=(seg - 1) + seg + seg * rho * k_seg)
    return per_seg.scaled(t * n_segments)


def dense_predict_ops(t: int, s: int, d: int) -> OpCount:
    """Baseline prediction: low-bit (4-bit MSB) multiply Q·Kᵀ — still mults."""
    return matmul_ops(t, s, d)


def dlzs_predict_ops(t: int, s: int, d: int) -> OpCount:
    """DLZS prediction: shift-only log-domain matmul (adds only)."""
    return shift_matmul_ops(t, s, d)


def dlzs_khat_ops(s: int, h: int, d: int) -> OpCount:
    """Cross-phase Key prediction K̂ = X · pow2(W_k): shift-only as well."""
    return shift_matmul_ops(s, d, h)


def star_total_ops(t: int, s: int, d: int, *, block_kv: int, k_ratio: float,
                   n_segments: int, rho: float, strict: bool = False,
                   ) -> OpCount:
    """Full STAR flow: DLZS predict + SADS select + SU-FA formal compute."""
    keep_ratio = k_ratio  # tile-level keep tracks the element top-k ratio
    return (dlzs_predict_ops(t, s, d)
            + sads_ops(t, s, k_ratio, n_segments, rho)
            + sufa_ops(t, s, d, block_kv, keep_ratio, strict))


def baseline_ds_ops(t: int, s: int, d: int, *, block_kv: int,
                    k_ratio: float) -> OpCount:
    """The ablation baseline (paper §VI-B): 4-bit multiply prediction +
    vanilla full sort + traditional FA on the kept tokens."""
    return (dense_predict_ops(t, s, d)
            + full_sort_topk_ops(t, s, k_ratio)
            + fa2_ops(t, max(block_kv, int(s * k_ratio)), d, block_kv))
