"""Substrate tests: data, compression, checkpoint, train loop, optimizer."""

import dataclasses

from _hypothesis_shim import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import SyntheticLM
from repro.optim import adafactor, adamw
from repro.optim.adamw import AdamWConfig
from repro.optim.adafactor import AdafactorConfig
from repro.optim.compression import CompressionCfg, compress_tree, \
    compressed_bytes

jax.config.update("jax_enable_x64", False)


# -- data --------------------------------------------------------------------

def test_synthetic_deterministic_and_position_keyed():
    ds = SyntheticLM(vocab=512, seq=64, global_batch=8)
    a = ds.batch(7)
    b = ds.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next tokens
    full = ds.batch(3)
    assert full["tokens"].shape == (8, 64)
    assert full["labels"].shape == (8, 64)
    # shard union == full batch rows
    s0 = ds.shard(3, 0, 2)["tokens"]
    s1 = ds.shard(3, 1, 2)["tokens"]
    assert s0.shape[0] + s1.shape[0] == 8


def test_synthetic_learnable_structure():
    ds = SyntheticLM(vocab=512, seq=64, global_batch=4)
    b = ds.batch(0)
    # copy structure: some labels are exactly predictable from history
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


# -- compression --------------------------------------------------------------

def test_int8_compression_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3
    cfg = CompressionCfg(kind="int8", block=128)
    ghat, resid = compress_tree({"g": g}, None, cfg)
    err = np.abs(np.asarray(ghat["g"] - g))
    scale = 3 * np.abs(np.asarray(g)).max() / 127
    assert err.max() <= scale
    np.testing.assert_allclose(np.asarray(ghat["g"] + resid["g"]),
                               np.asarray(g), rtol=1e-5, atol=1e-6)


def test_error_feedback_converges():
    """Sum of EF-compressed gradients -> sum of true gradients (bias-free)."""
    cfg = CompressionCfg(kind="topk", topk_ratio=0.25)
    key = jax.random.PRNGKey(1)
    ef = None
    total_hat = jnp.zeros((256,))
    total = jnp.zeros((256,))
    for i in range(30):
        g = {"g": jax.random.normal(jax.random.fold_in(key, i), (256,))}
        ghat, ef = compress_tree(g, ef, cfg)
        total_hat = total_hat + ghat["g"]
        total = total + g["g"]
    # residual is bounded, so averages converge
    err = np.linalg.norm(np.asarray(total_hat - total)) / \
        np.linalg.norm(np.asarray(total))
    assert err < 0.5
    # and the leftover residual accounts for the difference exactly
    np.testing.assert_allclose(np.asarray(total_hat + ef["g"]),
                               np.asarray(total), rtol=1e-4, atol=1e-4)


def test_compressed_bytes_accounting():
    g = {"a": jnp.zeros((1000,)), "b": jnp.zeros((32, 32))}
    dense = compressed_bytes(g, CompressionCfg(kind="none"))
    int8 = compressed_bytes(g, CompressionCfg(kind="int8"))
    topk = compressed_bytes(g, CompressionCfg(kind="topk", topk_ratio=0.05))
    assert int8 < dense / 3
    assert topk < dense / 5


# -- optimizers ----------------------------------------------------------------

def _quad_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array([[1.0, -1.0],
                                                              [2.0, 0.5]])}


@pytest.mark.parametrize("which", ["adamw", "adafactor"])
def test_optimizers_descend_quadratic(which):
    params = _quad_params()
    if which == "adamw":
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        state = adamw.adamw_init(params, cfg)
        upd = lambda p, g, s: adamw.adamw_update(p, g, s, cfg)
    else:
        cfg = AdafactorConfig(lr=0.3, weight_decay=0.0, min_dim_factored=2)
        state = adafactor.adafactor_init(params, cfg)
        upd = lambda p, g, s: adafactor.adafactor_update(p, g, s, cfg)
    loss = lambda p: sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(p))
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, gn = upd(params, grads, state)
    assert float(loss(params)) < 0.2 * l0
    assert np.isfinite(float(gn))


def test_adamw_grad_clip():
    params = {"w": jnp.zeros((4,))}
    cfg = AdamWConfig(lr=1.0, grad_clip=0.5, weight_decay=0.0)
    state = adamw.adamw_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    new_params, _, gn = adamw.adamw_update(params, huge, state, cfg)
    assert float(gn) > 1e5
    assert np.all(np.isfinite(np.asarray(new_params["w"])))
    assert np.abs(np.asarray(new_params["w"])).max() < 10.0


def test_adafactor_state_is_factored():
    cfg = AdafactorConfig(min_dim_factored=64)
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8,))}
    state = adafactor.adafactor_init(params, cfg)
    slots = state["slots"]
    assert set(slots["big"]) == {"r", "c"}
    assert slots["big"]["r"].shape == (256,)
    assert slots["big"]["c"].shape == (512,)
    assert set(slots["small"]) == {"v"}
    # factored state is ~0 bytes/param vs 4 for full fp32 moments
    factored = sum(l.size for l in jax.tree.leaves(slots))
    assert factored < params["big"].size / 100


# -- checkpointer -------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2, config_hash="h1")
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.array(5)}}
    for step in (10, 20, 30):
        ck.save(step, state, blocking=True)
    assert ck.all_steps() == [20, 30]   # keep=2 gc'd step 10
    like = jax.tree.map(lambda a: np.zeros_like(a), state)
    restored = ck.restore(30, like)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_config_hash_guard(tmp_path):
    ck = Checkpointer(tmp_path, config_hash="abc")
    ck.save(1, {"w": jnp.ones((2,))}, blocking=True)
    ck2 = Checkpointer(tmp_path, config_hash="DIFFERENT")
    with pytest.raises(ValueError, match="hash"):
        ck2.restore(1, {"w": np.zeros((2,))})


def test_checkpoint_partial_write_ignored(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, {"w": jnp.ones((2,))}, blocking=True)
    # a torn checkpoint without COMMITTED must be invisible
    (tmp_path / "step_000000009").mkdir()
    assert ck.latest_step() == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": jnp.ones((2,))}, blocking=True)
    with pytest.raises(ValueError, match="shape"):
        ck.restore(1, {"w": np.zeros((3,))})


@hypothesis.given(st.integers(1, 6), st.integers(1, 4))
@hypothesis.settings(deadline=None, max_examples=10)
def test_checkpoint_roundtrip_property(tmp_path_factory, a, b):
    tmp = tmp_path_factory.mktemp("ck")
    ck = Checkpointer(tmp)
    state = {"x": jnp.ones((a, b)) * a, "n": {"y": jnp.zeros((b,))}}
    ck.save(1, state, blocking=True)
    out = ck.restore(1, jax.tree.map(lambda t: np.zeros_like(t), state))
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(state["x"]))
