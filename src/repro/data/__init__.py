from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticLM, synthetic_batch

__all__ = ["ShardedLoader", "SyntheticLM", "synthetic_batch"]
