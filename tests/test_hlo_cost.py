"""Validate the HLO cost model against analytically known programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo

jax.config.update("jax_enable_x64", False)


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    m, k, n = 64, 128, 32
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    txt = _compile_text(lambda a, b: a @ b, a, b)
    costs = analyze_hlo(txt, 1)
    assert costs.flops == 2 * m * k * n
    # bytes: at least the three tensors once
    assert costs.bytes >= 4 * (m * k + k * n + m * n)


def test_scan_multiplies_by_trip_count():
    """THE critical property: a matmul inside lax.scan counts trip x."""
    m = 32
    a = jnp.zeros((m, m), jnp.float32)
    trips = 17

    def f(a):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, a, None, length=trips)
        return c

    txt = _compile_text(f, a)
    costs = analyze_hlo(txt, 1)
    assert costs.flops == trips * 2 * m ** 3, \
        f"{costs.flops} != {trips * 2 * m**3}"
    assert costs.n_while >= 1


def test_nested_scan_multiplies():
    m, outer, inner = 16, 5, 7
    a = jnp.zeros((m, m), jnp.float32)

    def f(a):
        def ibody(c, _):
            return c @ c, None

        def obody(c, _):
            c, _ = jax.lax.scan(ibody, c, None, length=inner)
            return c, None

        c, _ = jax.lax.scan(obody, a, None, length=outer)
        return c

    txt = _compile_text(f, a)
    costs = analyze_hlo(txt, 1)
    assert costs.flops == outer * inner * 2 * m ** 3


def test_dot_general_batched_contracting():
    b, m, k, n = 4, 8, 32, 16
    x = jnp.zeros((b, m, k), jnp.float32)
    y = jnp.zeros((b, k, n), jnp.float32)
    txt = _compile_text(lambda x, y: jnp.einsum("bmk,bkn->bmn", x, y), x, y)
    costs = analyze_hlo(txt, 1)
    assert costs.flops == 2 * b * m * k * n
