"""Scheduler policy unit tests against a fake executor (no device work),
plus SwapArea bookkeeping and paged-attention backend dispatch."""

import numpy as np
import pytest

from repro.kvcache import SwapArea
from repro.kvcache import paged_attention as pa
from repro.serving import Request
from repro.serving.scheduler import (AUTO_PREFILL_CHUNKS, AdmissionCfg,
                                     BudgetController, ExecFault, NeedPages,
                                     Scheduler, SchedulerCfg,
                                     resolve_prefill_tokens, sla_priority)
from repro.serving.swap_policy import RetryGovernor


class FakeEngine:
    """Host-only executor: a page budget instead of a device pool.

    Each sequence costs pages only while running; prefill takes
    ``chunks`` steps of one page each, decode grows one page per step
    until ``decode_steps`` run out. NeedPages fires exactly like the real
    engine: when the next page would exceed capacity.
    """

    def __init__(self, capacity: int, slots: int, chunks: dict,
                 decode_steps: dict):
        self.capacity = capacity
        self.free = list(range(slots))
        self.chunks = chunks                  # rid -> prefill chunk count
        self.decode_steps = decode_steps      # rid -> decode steps to run
        self.pages: dict[int, int] = {}       # slot -> pages held
        self.state: dict[int, dict] = {}      # slot -> progress
        self.swapped: dict[int, dict] = {}    # rid -> parked progress
        self.log: list = []
        self.preempt_held: list[int] = []     # pages each victim held

    def _used(self):
        return sum(self.pages.values())

    def free_slot_available(self):
        return bool(self.free)

    def exec_admit(self, req):
        slot = self.free.pop(0)
        self.pages[slot] = 0
        self.state[slot] = {"req": req, "chunk": 0,
                            "dec": self.decode_steps[req.rid]}
        self.log.append(("admit", req.rid))
        return slot

    def prefill_chunks_left(self, slot):
        st = self.state[slot]
        return self.chunks[st["req"].rid] - st["chunk"]

    def held_pages(self, slot, shard=None):
        return self.pages.get(slot, 0)

    def exec_prefill_chunk(self, slot):
        if self._used() + 1 > self.capacity:
            raise NeedPages(slot)
        st = self.state[slot]
        self.pages[slot] += 1
        st["chunk"] += 1
        self.log.append(("chunk", st["req"].rid))
        return self.prefill_chunks_left(slot) == 0

    def exec_decode(self):
        decode = [s for s in self.state
                  if self.prefill_chunks_left(s) == 0]
        for slot in decode:                   # grow before the step —
            st = self.state[slot]             # idempotent across retries,
            if not st.get("grown"):           # like the real block table
                if self._used() + 1 > self.capacity:
                    raise NeedPages(slot)
                self.pages[slot] += 1
                st["grown"] = True
        finished = []
        for slot in decode:
            st = self.state[slot]
            st["grown"] = False
            st["dec"] -= 1
            if st["dec"] <= 0:
                self.pages.pop(slot)
                self.state.pop(slot)
                self.free.append(slot)
                finished.append((slot, st["req"]))
        self.log.append(("decode", sorted(st["req"].rid for st in
                                          self.state.values())))
        return finished

    def exec_preempt(self, slot, swap):
        st = self.state.pop(slot)
        held = self.pages.pop(slot)
        self.free.append(slot)
        self.preempt_held.append(held)
        self.log.append(("preempt", st["req"].rid, swap))
        if swap:
            self.swapped[st["req"].rid] = {"st": st, "pages": held}
            return True
        return False

    def exec_swap_in(self, req):
        parked = self.swapped[req.rid]
        if self._used() + parked["pages"] > self.capacity:
            return None
        slot = self.free.pop(0)
        parked = self.swapped.pop(req.rid)
        self.pages[slot] = parked["pages"]
        self.state[slot] = parked["st"]
        self.log.append(("swap_in", req.rid))
        return slot


class BatchFakeEngine(FakeEngine):
    """FakeEngine speaking the batched varlen prefill protocol: every
    chunk costs 16 budget tokens (overridable per rid via ``widths``) and
    one capacity page, and a batch allocates all-or-nothing like the real
    engine's phase A."""

    def __init__(self, *a, widths=None, **kw):
        super().__init__(*a, **kw)
        self.widths = widths or {}

    def pending_chunk_widths(self, slot):
        w = self.widths.get(self.state[slot]["req"].rid, 16)
        return [w] * self.prefill_chunks_left(slot)

    def exec_prefill_chunk_batch(self, batch):
        if self._used() + sum(n for _, n in batch) > self.capacity:
            raise NeedPages(batch[0][0])
        self.log.append(("batch", sorted(
            self.state[s]["req"].rid for s, _ in batch)))
        done = []
        for slot, n in batch:
            st = self.state[slot]
            n = max(1, min(n, self.prefill_chunks_left(slot)))
            self.pages[slot] += n
            st["chunk"] += n
            for _ in range(n):
                self.log.append(("chunk", st["req"].rid))
            if self.prefill_chunks_left(slot) == 0:
                done.append(slot)
        return done


class SheddingFakeEngine(FakeEngine):
    """FakeEngine with lazy cold-page swap: everything but one hot (tail)
    page of a decoding sequence is sheddable, one page per call."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.shed_log: list[int] = []

    def exec_shed_cold(self, slot, shard=None):
        if self.prefill_chunks_left(slot) > 0:       # mid-prefill: no
            return 0                                 # past pages may leave
        if self.pages.get(slot, 0) <= 1:
            return 0
        self.pages[slot] -= 1
        self.shed_log.append(self.state[slot]["req"].rid)
        return 1


def _req(rid, priority=0):
    return Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                   priority=priority, out=[])


def _drain(sched, ex, max_ticks=200):
    done = []
    for _ in range(max_ticks):
        if not sched.has_work():
            return done
        done += sched.tick(ex)
    raise AssertionError("scheduler did not drain (deadlock?)")


def test_scheduler_prefill_interleaves_with_decode():
    """A long prefill advances one chunk per tick while an admitted short
    request decodes — decode never waits for the whole prompt."""
    ex = FakeEngine(capacity=100, slots=2,
                    chunks={0: 6, 1: 1}, decode_steps={0: 2, 1: 6})
    sched = Scheduler(SchedulerCfg(prefill_per_step=1))
    sched.submit(_req(0))                        # long prompt, first in line
    sched.submit(_req(1))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1}
    # request 1 (one chunk) decoded while request 0 was still prefilling
    first_decode = next(i for i, e in enumerate(ex.log)
                        if e[0] == "decode" and 1 in e[1])
    later_chunks = [e for e in ex.log[first_decode:] if e == ("chunk", 0)]
    assert later_chunks, "long prefill should still be running"


def test_scheduler_shortest_prefill_first():
    """Within a priority level the prompt with fewer remaining chunks
    prefills first (bounds short-request TTFT)."""
    ex = FakeEngine(capacity=100, slots=2,
                    chunks={0: 5, 1: 1}, decode_steps={0: 1, 1: 1})
    sched = Scheduler(SchedulerCfg(prefill_per_step=1))
    sched.submit(_req(0))
    sched.submit(_req(1))
    sched.tick(ex)
    assert ("chunk", 1) in ex.log                # short one went first
    assert ("chunk", 0) not in ex.log


def test_scheduler_aging_unstarves_long_prefill():
    """SJF alone would park a long prompt behind a stream of short ones;
    aging forces a chunk of the long prefill through every
    ``starvation_ticks`` ticks."""
    chunks = {0: 6}
    decode = {0: 1}
    for rid in range(1, 9):                      # sustained short stream
        chunks[rid] = 1
        decode[rid] = 1
    ex = FakeEngine(capacity=100, slots=3, chunks=chunks,
                    decode_steps=decode)
    sched = Scheduler(SchedulerCfg(prefill_per_step=1, starvation_ticks=2))
    for rid in sorted(chunks):
        sched.submit(_req(rid))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == set(chunks)
    # the long prompt's chunks interleave with the short stream instead of
    # all trailing it: at least one lands before the last short's chunk
    chunk_rids = [e[1] for e in ex.log if e[0] == "chunk"]
    last_short = max(i for i, r in enumerate(chunk_rids) if r != 0)
    assert any(r == 0 for r in chunk_rids[:last_short]), \
        "long prefill was starved until the short stream drained"


def test_scheduler_token_budget_batches_prefill():
    """With ``prefill_tokens`` set, ONE batched dispatch per tick advances
    every prefilling sequence that packs under the budget — not one
    dispatch per sequence — and everything still completes."""
    ex = BatchFakeEngine(capacity=100, slots=4,
                         chunks={0: 2, 1: 2, 2: 2, 3: 2},
                         decode_steps={r: 2 for r in range(4)})
    sched = Scheduler(SchedulerCfg(chunk_pages=1, prefill_tokens=48))
    for rid in range(4):
        sched.submit(_req(rid))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1, 2, 3}
    batches = [e[1] for e in ex.log if e[0] == "batch"]
    assert batches, "no batched dispatch was issued"
    # 48-token budget = 3 chunks per dispatch: the first tick packs 3
    # sequences into one dispatch
    assert len(batches[0]) == 3
    # one dispatch per tick: #batches < #chunks issued
    n_chunks = sum(len(b) for b in batches)
    assert len(batches) < n_chunks


def test_scheduler_budget_head_chunk_always_advances():
    """A chunk wider than the whole budget still makes progress — it is
    dispatched alone (the flat buffer is sized to hold any single
    chunk)."""
    ex = BatchFakeEngine(capacity=100, slots=2, chunks={0: 1, 1: 1},
                         decode_steps={0: 1, 1: 1},
                         widths={0: 128, 1: 16})
    sched = Scheduler(SchedulerCfg(chunk_pages=1, prefill_tokens=32))
    sched.submit(_req(0))
    sched.submit(_req(1))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1}
    batches = [e[1] for e in ex.log if e[0] == "batch"]
    # the 128-wide chunk went alone; the 16-wide one got its own dispatch
    assert [1] in batches and [0] in batches


def test_scheduler_batched_prefill_pressure_preempts_and_finishes():
    """NeedPages from a batched dispatch picks a victim and retries with a
    re-packed batch; overload degrades, never deadlocks."""
    ex = BatchFakeEngine(capacity=4, slots=3,
                         chunks={0: 1, 1: 1, 2: 1},
                         decode_steps={0: 3, 1: 3, 2: 3})
    sched = Scheduler(SchedulerCfg(chunk_pages=1, prefill_tokens=64,
                                   swap=True))
    for rid in range(3):
        sched.submit(_req(rid))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1, 2}
    assert sched.stats.preemptions > 0


def test_scheduler_lazy_shed_keeps_victim_running():
    """Pressure relief via lazy cold-page swap: with ``lazy_swap`` the
    scheduler first asks victims to shed cold pages — sequences keep
    decoding on their hot sets, nobody is stopped, and the shed counter
    (not the preemption counter) moves."""
    ex = SheddingFakeEngine(capacity=4, slots=2, chunks={0: 1, 1: 1},
                            decode_steps={0: 4, 1: 4})
    sched = Scheduler(SchedulerCfg(swap=True, lazy_swap=True))
    sched.submit(_req(0))
    sched.submit(_req(1))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1}
    assert sched.stats.sheds > 0
    assert sched.stats.preemptions == 0
    assert not [e for e in ex.log if e[0] == "preempt"]
    assert ex.shed_log                       # pages actually left victims


def test_scheduler_lazy_shed_falls_back_to_preemption():
    """When nothing is sheddable (every page hot), lazy mode must still
    fall back to ordinary preemption rather than spin."""
    ex = FakeEngine(capacity=4, slots=3,
                    chunks={0: 1, 1: 1, 2: 1},
                    decode_steps={0: 3, 1: 3, 2: 3})
    ex.exec_shed_cold = lambda slot, shard=None: 0
    sched = Scheduler(SchedulerCfg(swap=True, lazy_swap=True))
    for rid in range(3):
        sched.submit(_req(rid))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1, 2}
    assert sched.stats.sheds == 0
    assert sched.stats.preemptions > 0


def test_scheduler_preempts_lowest_priority_newest():
    # per-sequence worst case (1 prefill + 3 decode pages) fits capacity —
    # the invariant the real engine's submit() enforces
    ex = FakeEngine(capacity=4, slots=3,
                    chunks={0: 1, 1: 1, 2: 1},
                    decode_steps={0: 3, 1: 3, 2: 3})
    sched = Scheduler(SchedulerCfg(swap=True))
    sched.submit(_req(0, priority=1))
    sched.submit(_req(1, priority=0))            # victim: low prio...
    sched.submit(_req(2, priority=0))            # ...and newest
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1, 2}
    victims = [e[1] for e in ex.log if e[0] == "preempt"]
    assert victims and 0 not in victims          # high priority never evicted
    # page-aware victim selection: preempting a page-less slot frees
    # nothing, so every victim must have held pages
    assert all(h > 0 for h in ex.preempt_held)
    assert sched.stats.preemptions == len(victims)
    assert sched.stats.resumes >= 1              # swapped work came back


def test_scheduler_low_priority_arrival_cannot_evict_high():
    """A low-priority request that cannot get pages defers itself; it
    must never preempt a strictly higher-priority running sequence."""
    # rid 0 (priority 5) needs the whole pool; rid 1 (priority 0) arrives
    # while it runs and cannot fit until it finishes
    ex = FakeEngine(capacity=4, slots=2, chunks={0: 1, 1: 1},
                    decode_steps={0: 3, 1: 3})
    sched = Scheduler(SchedulerCfg(swap=True))
    sched.submit(_req(0, priority=5))
    sched.submit(_req(1, priority=0))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1}
    victims = [e[1] for e in ex.log if e[0] == "preempt"]
    assert victims and 0 not in victims          # rid 1 defers itself


def test_scheduler_recompute_mode_requeues():
    ex = FakeEngine(capacity=3, slots=2, chunks={0: 1, 1: 1},
                    decode_steps={0: 2, 1: 2})
    sched = Scheduler(SchedulerCfg(swap=False))
    sched.submit(_req(0))
    sched.submit(_req(1))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1}
    assert sched.stats.recomputes == sched.stats.preemptions > 0
    assert sched.stats.swap_outs == 0


def test_scheduler_blocked_swap_in_holds_the_line():
    """A preempted sequence resumes before any later arrival of the same
    priority is admitted — even across ticks where the swap-in does not
    fit yet but the fresh request would (no starvation of swapped work)."""
    ex = FakeEngine(capacity=4, slots=2, chunks={0: 2, 1: 1, 2: 1},
                    decode_steps={0: 2, 1: 3, 2: 1})
    sched = Scheduler(SchedulerCfg(swap=True))
    for rid in (0, 1, 2):
        sched.submit(_req(rid))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1, 2}
    assert ("preempt", 1, True) in ex.log        # rid 1 was swapped out...
    assert ex.log.index(("swap_in", 1)) < ex.log.index(("admit", 2))


def test_scheduler_sla_classes_map_to_priority():
    """The external QoS input: an SLA class on the request becomes a
    scheduler priority at submit — interactive outranks standard outranks
    batch — and an explicit priority is what preemption ranks by."""
    assert sla_priority("interactive") > sla_priority("standard") \
        > sla_priority("batch")
    with pytest.raises(ValueError, match="SLA"):
        sla_priority("platinum")
    # batch traffic is the preemption victim; interactive never is
    ex = FakeEngine(capacity=4, slots=3,
                    chunks={0: 1, 1: 1, 2: 1},
                    decode_steps={0: 3, 1: 3, 2: 3})
    sched = Scheduler(SchedulerCfg(swap=True))
    sched.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                         sla="interactive", out=[]))
    sched.submit(Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                         sla="batch", out=[]))
    sched.submit(Request(rid=2, prompt=np.arange(4, dtype=np.int32),
                         sla="batch", out=[]))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1, 2}
    victims = [e[1] for e in ex.log if e[0] == "preempt"]
    assert victims and 0 not in victims


class ShardedFakeEngine(FakeEngine):
    """FakeEngine with two page shards: even slots hold pages on shard 0,
    odd slots on shard 1 (a stand-in for the spatial engine's striping)."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.last_need_shard = None
        self.victim_shards_ok: list[bool] = []

    def held_pages(self, slot, shard=None):
        if shard is not None and slot % 2 != shard:
            return 0
        return self.pages.get(slot, 0)

    def exec_decode(self):
        decode = [s for s in self.state
                  if self.prefill_chunks_left(s) == 0]
        for slot in decode:        # growth raises with the slot's shard;
            st = self.state[slot]  # super() then sees grown=True and skips
            if not st.get("grown"):
                if self._used() + 1 > self.capacity:
                    self.last_need_shard = slot % 2
                    raise NeedPages(slot, shard=slot % 2)
                self.pages[slot] += 1
                st["grown"] = True
        return super().exec_decode()

    def exec_preempt(self, slot, swap):
        if self.last_need_shard is not None:
            self.victim_shards_ok.append(slot % 2 == self.last_need_shard)
        return super().exec_preempt(slot, swap)


def test_scheduler_shard_tagged_pressure_picks_shard_victim():
    """A NeedPages tagged with a shard must evict a victim that frees
    pages on THAT shard — evicting elsewhere would not unblock the needy
    sequence (the spatial engine's per-shard pools)."""
    # per-sequence worst case (1 prefill + 4 decode pages) fits capacity
    ex = ShardedFakeEngine(capacity=5, slots=3,
                           chunks={0: 1, 1: 1, 2: 1},
                           decode_steps={0: 4, 1: 4, 2: 4})
    sched = Scheduler(SchedulerCfg(swap=True))
    for rid in (0, 1, 2):
        sched.submit(_req(rid))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1, 2}
    assert sched.stats.preemptions > 0
    assert all(h > 0 for h in ex.preempt_held)
    # every shard-tagged preemption freed pages on the starved shard
    assert ex.victim_shards_ok and all(ex.victim_shards_ok)


def test_budget_controller_tracks_tick_times():
    """The ``prefill_tokens="auto"`` EMA controller: fast ticks grow the
    packing budget toward the compiled buffer width, slow ticks shrink
    it toward one chunk — always quantized and inside [lo, hi]."""
    ctl = BudgetController(lo=32, hi=256, quantum=16, target_s=0.1)
    assert ctl.budget == 256                 # optimistic start
    for _ in range(8):                       # very slow hardware:
        ctl.observe(1.0, 64)                 # 1 s for 64 tokens
        assert ctl.lo <= ctl.budget <= ctl.hi
        assert ctl.budget % 16 == 0
    assert ctl.budget == 32                  # clamped to the floor
    for _ in range(16):                      # very fast hardware
        ctl.observe(0.0001, 64)
    assert ctl.budget == 256                 # back to the ceiling
    # EMA smooths: one 100x OS-stall outlier must not collapse the budget
    ctl.observe(0.01, 64)
    assert ctl.budget == 256
    # degenerate observations are ignored
    b = ctl.budget
    ctl.observe(0.5, 0)
    ctl.observe(-1.0, 64)
    assert ctl.budget == b


def test_budget_controller_steers_to_target():
    """At a stable per-token cost the budget converges to ~target_s
    worth of tokens (quantized)."""
    ctl = BudgetController(lo=16, hi=4096, quantum=16, target_s=0.1)
    for _ in range(32):
        ctl.observe(0.001 * ctl.budget, ctl.budget)   # 1 ms per token
    assert ctl.budget == 96                  # 0.1 s / 1 ms -> 100 -> 96


def test_prefill_tokens_auto_resolution_and_scheduler_wiring():
    """"auto" resolves to an AUTO_PREFILL_CHUNKS-chunk buffer; the
    scheduler self-installs a controller (with placeholder bounds until
    the engine attaches real ones) and a full fake-engine run completes
    with the controller live."""
    assert resolve_prefill_tokens(
        SchedulerCfg(chunk_pages=2, prefill_tokens="auto"), 16) \
        == AUTO_PREFILL_CHUNKS * 2 * 16
    assert resolve_prefill_tokens(
        SchedulerCfg(chunk_pages=2, prefill_tokens=48), 16) == 48
    assert resolve_prefill_tokens(
        SchedulerCfg(chunk_pages=None, prefill_tokens="auto"), 16) is None
    assert resolve_prefill_tokens(
        SchedulerCfg(chunk_pages=2, prefill_tokens=None), 16) is None

    ex = BatchFakeEngine(capacity=100, slots=4,
                         chunks={0: 2, 1: 2, 2: 2, 3: 2},
                         decode_steps={r: 2 for r in range(4)})
    sched = Scheduler(SchedulerCfg(chunk_pages=1, prefill_tokens="auto"))
    assert sched.budget_ctl is not None
    sched.attach_budget(lo=16, hi=64, quantum=16)
    assert sched.prefill_budget() == 64
    for rid in range(4):
        sched.submit(_req(rid))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1, 2, 3}
    # the controller saw real tick observations and stayed in bounds
    assert 16 <= sched.budget_ctl.budget <= 64


class AbortLogFakeEngine(FakeEngine):
    """FakeEngine recording the terminal aborts the scheduler issues
    (quarantines and admission sheds)."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.aborts: list[tuple[int, str, str]] = []

    def exec_abort(self, req, outcome, reason):
        self.aborts.append((req.rid, outcome, reason))


class FailingSwapInFakeEngine(AbortLogFakeEngine):
    """Swap-in fails ``fail_swap_ins`` times; the parked payload is
    discarded on failure (the real engine's rollback contract), so the
    scheduler's only road back is recompute-from-prompt."""

    def __init__(self, *a, fail_swap_ins=1, **kw):
        super().__init__(*a, **kw)
        self.fail_swap_ins = fail_swap_ins

    def exec_swap_in(self, req):
        if self.fail_swap_ins > 0:
            self.fail_swap_ins -= 1
            self.swapped.pop(req.rid)          # payload already discarded
            self.log.append(("swap_in_fault", req.rid))
            raise ExecFault([], RuntimeError("payload corrupt"),
                            "swap_in", rid=req.rid)
        return super().exec_swap_in(req)


class DecodeFaultFakeEngine(AbortLogFakeEngine):
    """Decode always dies on ``bad_rid``'s slot — the unrecoverable-
    request case that must exhaust the retry budget and quarantine."""

    def __init__(self, *a, bad_rid=0, **kw):
        super().__init__(*a, **kw)
        self.bad_rid = bad_rid

    def exec_decode(self):
        for slot, st in self.state.items():
            if (st["req"].rid == self.bad_rid
                    and self.prefill_chunks_left(slot) == 0):
                raise ExecFault([slot], RuntimeError("nan"), "decode")
        return super().exec_decode()


def test_retry_governor_budget_and_backoff():
    """The fault budget is exact: ``max_retries`` linearly-backed-off
    retries, then None (quarantine); a clean finish resets the count."""
    gov = RetryGovernor(max_retries=2, backoff_ticks=3)
    assert gov.record_fault(7) == 3              # attempt 1
    assert gov.attempts(7) == 1
    assert gov.record_fault(7) == 6              # attempt 2
    assert gov.record_fault(7) is None           # budget spent
    gov.forget(7)
    assert gov.attempts(7) == 0
    assert gov.record_fault(7) == 3              # budget restored


def test_scheduler_failed_swap_in_falls_back_to_recompute_once():
    """A failed page-in consumes exactly one retry: the request re-enters
    as a recompute (fresh admit, page table rebuilt from the prompt),
    completes, and no page or parked payload leaks."""
    # the blocked-swap-in topology: rid 1 is swapped out under pressure
    # and must come back — here its one page-in attempt fails
    ex = FailingSwapInFakeEngine(capacity=4, slots=2,
                                 chunks={0: 2, 1: 1, 2: 1},
                                 decode_steps={0: 2, 1: 3, 2: 1},
                                 fail_swap_ins=1)
    sched = Scheduler(SchedulerCfg(swap=True, fault_retries=2))
    for rid in (0, 1, 2):
        sched.submit(_req(rid))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {0, 1, 2}
    assert ("preempt", 1, True) in ex.log        # parked under pressure...
    assert ("swap_in_fault", 1) in ex.log        # ...page-in failed...
    admits = [e for e in ex.log if e == ("admit", 1)]
    assert len(admits) == 2                      # ...recompute re-admit
    assert sched.stats.faults == 1
    assert sched.stats.fault_retries == 1        # exactly one retry spent
    assert sched.stats.quarantines == 0 and not ex.aborts
    # watchdog clean: nothing running, parked, or holding pages
    assert not ex.pages and not ex.state and not ex.swapped
    assert not sched._retry.counts               # clean finish forgets


def test_scheduler_fault_budget_exhaustion_quarantines():
    """An unrecoverable request gets exactly ``fault_retries`` recompute
    retries, then quarantines into FAILED via exec_abort — co-resident
    requests finish undisturbed and no pages leak."""
    ex = DecodeFaultFakeEngine(capacity=100, slots=2,
                               chunks={0: 1, 1: 1},
                               decode_steps={0: 2, 1: 4}, bad_rid=0)
    sched = Scheduler(SchedulerCfg(fault_retries=2, fault_backoff_ticks=1))
    sched.submit(_req(0))
    sched.submit(_req(1))
    done = _drain(sched, ex)
    assert {r.rid for r in done} == {1}          # survivor unaffected
    admits = [e for e in ex.log if e == ("admit", 0)]
    assert len(admits) == 1 + 2                  # initial + retry budget
    assert sched.stats.fault_retries == 2
    assert sched.stats.quarantines == 1
    assert ex.aborts == [(0, "failed", "decode:RuntimeError")]
    # the fault path drops pages via the recompute preemption (not
    # counted as a scheduler preemption) — nothing leaks
    assert sched.stats.preemptions == 0
    assert not ex.pages and not ex.state and not ex.swapped


def test_scheduler_admission_shedding_hysteresis():
    """Backlog over the high watermark sheds fresh best-effort arrivals
    (newest first) down to the low watermark; between the watermarks the
    gate stays open — no flapping — and standard traffic is never shed."""
    ex = AbortLogFakeEngine(capacity=100, slots=1,
                            chunks={r: 1 for r in range(8)},
                            decode_steps={r: 2 for r in range(8)})
    sched = Scheduler(SchedulerCfg(admission=AdmissionCfg(
        high_watermark=4, low_watermark=2, shed_below_priority=0)))
    sched.submit(_req(0))                        # admitted immediately
    fins = sched.tick(ex)
    for rid in (1, 2):
        sched.submit(_req(rid, priority=-10))    # batch backlog
    sched.submit(_req(3))                        # standard backlog
    fins += sched.tick(ex)
    assert sched.stats.admission_sheds == 0      # 3 < high watermark
    for rid in (4, 5):
        sched.submit(_req(rid, priority=-10))
    fins += sched.tick(ex)                       # backlog 5 >= 4: shed
    # newest batch arrivals go first, down to the low watermark of 2
    assert sched.stats.admission_sheds == 3
    assert [a[:2] for a in ex.aborts] == [(5, "cancelled"),
                                          (4, "cancelled"),
                                          (2, "cancelled")]
    assert all(a[2] == "admission_shed" for a in ex.aborts)
    # recovered to the low watermark: the gate reopens, so a fresh batch
    # arrival is admitted, not shed — hysteresis, no flapping
    sched.submit(_req(6, priority=-10))
    fins += _drain(sched, ex)
    assert {r.rid for r in fins} == {0, 1, 3, 6}
    assert sched.stats.admission_sheds == 3


def test_swap_area_bookkeeping():
    area = SwapArea()
    area.put(7, {"x": 1}, 100)
    area.put(9, {"y": 2}, 50)
    assert 7 in area and len(area) == 2
    assert area.peek(7) == {"x": 1}
    assert area.stats().bytes == 150 and area.stats().peak_bytes == 150
    assert area.take(7) == {"x": 1}
    assert 7 not in area and area.stats().bytes == 50
    assert area.stats().swap_outs == 2 and area.stats().swap_ins == 1
    with pytest.raises(AssertionError):
        area.put(9, {}, 1)                       # double-park is a bug


def test_paged_backend_dispatch(monkeypatch):
    monkeypatch.delenv("REPRO_PAGED_BACKEND", raising=False)
    import jax
    want = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert pa.default_backend() == want
    monkeypatch.setenv("REPRO_PAGED_BACKEND", "pallas")
    assert pa.default_backend() == "pallas"
    monkeypatch.setenv("REPRO_PAGED_BACKEND", "xla")
    assert pa.default_backend() == "xla"
    monkeypatch.setenv("REPRO_PAGED_BACKEND", "mosaic")
    with pytest.raises(ValueError, match="REPRO_PAGED_BACKEND"):
        pa.default_backend()
    assert pa.default_interpret() == (jax.default_backend() != "tpu")