"""Quickstart: STAR sparse attention in three stages, on one head.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (STARConfig, dense_attention, dlzs_scores, lz_pack,
                        pow2_quantize, sads_select_blocks, star_attention)
from repro.core.opcount import fa2_ops, star_total_ops

T, S, D = 512, 2048, 128
keys = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(keys[0], (T, D))
k = jax.random.normal(keys[1], (S, D)).at[: S // 16].mul(3.0)  # peaked rows
v = jax.random.normal(keys[2], (S, D))
scale = 1.0 / np.sqrt(D)

# --- Stage 1: DLZS — multiplier-free score prediction ----------------------
k_pow2 = pow2_quantize(k)          # sign·2^floor(log2|k|): the LZ operand
s_hat = dlzs_scores(q, k_pow2, scale)
print(f"DLZS: predicted scores {s_hat.shape}; LZ cache is int8 "
      f"({lz_pack(k).nbytes / k.nbytes:.2f}x the bf16 bytes)")

# --- Stage 2: SADS — segmented top-k with sphere pruning --------------------
cfg = STARConfig(top_k_ratio=0.2, block_q=128, block_kv=128, radius=5.0)
sel = sads_select_blocks(s_hat, cfg.block_q, cfg.block_kv,
                         cfg.keep_blocks(S), radius=cfg.radius)
print(f"SADS: each of {T // cfg.block_q} query tiles keeps "
      f"{sel.block_idx.shape[-1]}/{S // cfg.block_kv} KV tiles "
      f"(descending predicted max -> SU-FA order)")

# --- Stage 3: SU-FA — sorted-updating sparse attention ----------------------
out = star_attention(q, k, v, cfg, causal=False)
ref = dense_attention(q, k, v, causal=False)
err = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
print(f"STAR vs dense attention: rel error {err:.3f} at "
      f"{cfg.top_k_ratio:.0%} kept")

# --- complexity (paper Fig. 18) ---------------------------------------------
fa = fa2_ops(T, S, D, 128).equivalent_adds
star = star_total_ops(T, S, D, block_kv=128, k_ratio=0.2, n_segments=S // 128,
                      rho=0.4, strict=False).equivalent_adds
print(f"equivalent-adds: FA-2 {fa:.2e} vs STAR {star:.2e} "
      f"({1 - star / fa:.0%} reduction)")
