"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Drives the unified serving front door (``repro.serving.api.LLM``) over
one of the three backends:

* ``--engine paged``   — the default: the paged KV-cache engine with
  chunked prefill and the preemption scheduler (batched varlen prefill
  with the ``prefill_tokens="auto"`` budget controller by default).
* ``--engine spatial`` — the sequence-sharded multi-device runtime
  (``--shards N``): context length scales with device count. When the
  process has fewer devices than shards it re-executes itself with
  ``xla_force_host_platform_device_count`` set, so the fake-device
  harness works out of the box on a laptop.
* ``--engine dense``   — the retired slot-based engine, kept as the
  parity oracle and footprint baseline (tests/benchmarks); serve it
  only to compare against the pool-backed engines.

``--disagg`` serves through the prefill/decode-disaggregated router
instead (``repro.serving.disagg``, docs/disaggregation.md): submits
land on a prefill-tuned instance of the chosen backend and the
KVTransfer fabric hands each request to a paged decode-tuned instance
at the phase boundary.

Requests carry an SLA class (``--sla-mix`` cycles interactive / standard
/ batch) that the scheduler maps onto priorities: interactive traffic is
admitted first and preempted last. ``--sla-deadlines`` enforces the
SLA-tier default TTFT/end-to-end budgets and ``--shed-watermarks HIGH
LOW`` turns on hysteresis admission shedding of low-priority traffic
under backlog (see docs/serving.md, "Robustness"). Smoke configs serve
on CPU; ``--full --mesh`` builds the production mesh exactly as the
dry-run does.

Telemetry (``repro.obs``, see docs/observability.md) is on by default:

* ``--trace PATH`` exports a Perfetto/Chrome trace of the run
  (``.jsonl`` suffix streams JSONL, anything else writes Chrome JSON)
  and prints the per-phase time table (``tools/trace_summary.py``);
* ``--metrics TARGET`` writes the Prometheus text exposition of the
  run's ``MetricsRegistry`` — ``-`` for stdout, else a file path (point
  a node_exporter textfile collector at it);
* ``--no-telemetry`` serves with the no-op ``NULL_TELEMETRY`` (the
  library default), dropping per-token timestamps and the surfaces
  above.
"""

from __future__ import annotations

import argparse
import sys
import time

SLA_CYCLE = ("interactive", "standard", "batch")


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", default="paged",
                    choices=("dense", "paged", "spatial"))
    ap.add_argument("--disagg", action="store_true",
                    help="prefill/decode disaggregation: serve through "
                         "a (prefill-tuned, decode-tuned) instance pair "
                         "of --engine joined by the KVTransfer fabric "
                         "(paged/spatial)")
    ap.add_argument("--shards", type=int, default=2,
                    help="sequence shards (spatial engine)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=64,
                    help="pool pages (paged: total; spatial: per shard)")
    ap.add_argument("--sla-mix", action="store_true",
                    help="cycle requests through interactive/standard/"
                         "batch SLA classes")
    ap.add_argument("--sla-deadlines", action="store_true",
                    help="enforce the SLA-tier default TTFT/e2e deadline "
                         "budgets (paged/spatial; expired requests end "
                         "with outcome 'expired')")
    ap.add_argument("--shed-watermarks", nargs=2, type=int, default=None,
                    metavar=("HIGH", "LOW"),
                    help="enable admission shedding (paged/spatial): shed "
                         "sheddable waiting requests when the backlog "
                         "crosses HIGH, until it is back at LOW")
    ap.add_argument("--shed-below-priority", type=int, default=0,
                    help="with --shed-watermarks: only requests below "
                         "this priority are sheddable (0 sheds 'batch' "
                         "but never 'standard'/'interactive')")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="export a Perfetto/Chrome trace of the run "
                         "(.jsonl streams JSONL) and print the per-phase "
                         "time table")
    ap.add_argument("--metrics", metavar="TARGET", default=None,
                    help="Prometheus text exposition after the run: "
                         "'-' for stdout, else a file path")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="serve with the no-op telemetry (the library "
                         "default); --trace/--metrics are ignored")
    return ap.parse_args(argv)


def main(argv=None):
    args = _parse_args(argv)

    if args.engine == "spatial":
        # the XLA device count is fixed at first jax init: grow it in a
        # child process when this one is too small for the mesh
        import jax
        if len(jax.devices()) < args.shards:
            from repro.spatial import respawn_with_devices
            sys.exit(respawn_with_devices(
                args.shards, ["-m", "repro.launch.serve"]
                + (argv if argv is not None else sys.argv[1:])))

    import dataclasses
    import pathlib

    import jax
    import numpy as np

    from repro import obs
    from repro.configs import ARCHS, get_config, get_smoke_config
    from repro.models import lm
    from repro.serving import (LLM, AdmissionCfg, EngineCfg,
                               PagedEngineCfg, SchedulerCfg)
    from repro.spatial import SpatialEngineCfg

    if args.arch not in ARCHS:
        raise SystemExit(f"unknown arch {args.arch}; choose from "
                         f"{sorted(ARCHS)}")
    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if cfg.enc_layers or cfg.embeds_input:
        raise SystemExit(f"{args.arch}: frontend-stub archs serve via "
                         "examples/ drivers")
    if args.engine == "spatial" and cfg.star is not None:
        cfg = dataclasses.replace(cfg, star=None)
    params = lm.init(jax.random.PRNGKey(0), cfg)

    if args.engine == "dense":
        engine_cfg = EngineCfg(max_batch=args.slots, max_len=args.max_len,
                               eos_id=-1)
    elif args.engine == "paged":
        engine_cfg = PagedEngineCfg(
            max_batch=args.slots, page_size=args.page_size,
            n_pages=args.pages, hot_pages=args.max_len // args.page_size,
            eos_id=-1)
    else:
        engine_cfg = SpatialEngineCfg(
            n_shards=args.shards, max_batch=args.slots,
            page_size=args.page_size, n_pages_local=args.pages,
            hot_pages_local=args.max_len // args.page_size, eos_id=-1)
    sched_cfg = None
    if args.sla_deadlines or args.shed_watermarks:
        if args.engine == "dense":
            print("[serve] --sla-deadlines/--shed-watermarks ignored on "
                  "the dense engine (no scheduler; per-request deadlines "
                  "still apply via submit())")
        else:
            admission = None
            if args.shed_watermarks:
                high, low = args.shed_watermarks
                admission = AdmissionCfg(
                    high_watermark=high, low_watermark=low,
                    shed_below_priority=args.shed_below_priority)
            sched_cfg = SchedulerCfg(prefill_tokens="auto",
                                     sla_deadlines=args.sla_deadlines,
                                     admission=admission)
    tel = None if args.no_telemetry else obs.Telemetry(
        {"launcher": "repro.launch.serve", "engine": args.engine,
         "arch": args.arch, "disagg": args.disagg})
    if args.disagg:
        if args.engine == "dense":
            raise SystemExit("--disagg needs a pool-backed engine "
                             "(paged/spatial)")
        from repro.serving import DisaggRouter
        llm = DisaggRouter.from_config(
            cfg, backend="paged", prefill_backend=args.engine,
            params=params, shards=args.shards,
            prefill_engine_cfg=engine_cfg if args.engine != "paged"
            else None,
            prefill_sched_cfg=sched_cfg, telemetry=tel)
    else:
        llm = LLM.from_config(cfg, backend=args.engine, params=params,
                              shards=args.shards, engine_cfg=engine_cfg,
                              sched_cfg=sched_cfg, telemetry=tel)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        llm.submit(rng.integers(0, cfg.vocab, size=args.prompt_len,
                                dtype=np.int32),
                   max_tokens=args.max_tokens,
                   sla=SLA_CYCLE[i % len(SLA_CYCLE)]
                   if args.sla_mix else None)
    done = llm.run_until_done()
    rep = llm.metrics()
    n_tok = rep.get("tokens", sum(len(v) for v in done.values()))
    extra = ""
    if rep.get("requests"):
        extra = f", ttft_p50={rep['ttft_p50_ms']}ms"
        if rep.get("occupancy") is not None:
            extra += f", occupancy={rep['occupancy']}"
        if args.sla_mix:
            extra += "".join(
                f", {k}={v['ttft_mean_ms']}ms"
                for k, v in rep["per_sla"].items()
                if v["ttft_mean_ms"] is not None)
        abnormal: dict = {}
        for v in rep.get("per_sla", {}).values():
            for outcome, n in v.get("outcomes", {}).items():
                if outcome != "done":
                    abnormal[outcome] = abnormal.get(outcome, 0) + n
        if abnormal:
            extra += ", " + ", ".join(
                f"{k}={n}" for k, n in sorted(abnormal.items()))
    if args.disagg:
        tr = llm.transfer.stats()
        extra += (f", transfers={tr['n_transfers']}"
                  f", transfer_bytes={tr['bytes_total']}")
    dt = time.time() - t0
    shards = f", {args.shards} shards" if args.engine == "spatial" else ""
    mode = ", disagg" if args.disagg else ""
    print(f"[serve] {args.arch} ({'full' if args.full else 'smoke'}, "
          f"{args.engine}{shards}{mode}): "
          f"{len(done)} requests, {n_tok} tokens, "
          f"{n_tok / dt:.1f} tok/s, star={'on' if cfg.star else 'off'}"
          f"{extra}")

    if args.trace:
        if tel is None:
            print("[serve] --trace ignored (telemetry disabled)")
        else:
            path = pathlib.Path(args.trace)
            if path.parent != pathlib.Path("."):
                path.parent.mkdir(parents=True, exist_ok=True)
            if path.suffix == ".jsonl":
                tel.tracer.export_jsonl(str(path))
            else:
                tel.tracer.export_chrome(str(path))
            print(obs.format_table(obs.phase_summary(tel.tracer.events),
                                   title=args.engine))
            print(f"[serve] trace -> {path} "
                  f"(load at https://ui.perfetto.dev)")

    if args.metrics:
        if tel is None:
            print("[serve] --metrics ignored (telemetry disabled)")
        else:
            text = tel.metrics.render_prometheus()
            if args.metrics == "-":
                sys.stdout.write(text)
            else:
                pathlib.Path(args.metrics).write_text(text)
                print(f"[serve] metrics -> {args.metrics} "
                      f"({len(text.splitlines())} lines)")


if __name__ == "__main__":
    main()
