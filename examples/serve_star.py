"""Serve a small LM with batched requests through the continuous-batching
engine, with STAR sparse decode against the int8 LZ prediction cache.

Run:  PYTHONPATH=src python examples/serve_star.py
"""

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving import EngineCfg, ServingEngine
from repro.serving.engine import Request


def main():
    cfg = get_smoke_config("star_paper")   # STAR sparse decode enabled
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params,
                        EngineCfg(max_batch=4, max_len=192, eos_id=-1))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=24,
                                        dtype=np.int32),
                    max_tokens=16)
            for i in range(10)]

    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests / {n_tok} tokens through "
          f"{eng.ecfg.max_batch} continuous-batching slots in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s on CPU)")
    for rid in sorted(done)[:3]:
        print(f"  req {rid}: {done[rid][:8]}...")
    assert len(done) == len(reqs)


if __name__ == "__main__":
    main()
