"""Pure-jnp oracles for every Pallas kernel (assert_allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_ref(q, k, v, *, causal=True, scale=None):
    """Dense softmax attention, fp32 statistics. q [BH,T,d] -> [BH,T,d]."""
    bh, t, d = q.shape
    s = k.shape[1]
    scale = scale or (1.0 / math.sqrt(d))
    sc = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(s)[None, :] <= jnp.arange(t)[:, None] + (s - t)
        sc = jnp.where(mask, sc, NEG_INF)
    m = sc.max(axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    p = jnp.where(sc <= NEG_INF / 2, 0.0, p)
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bts,bsd->btd", p / l, v.astype(jnp.float32))
    return o.astype(q.dtype)


def sufa_ref(q, kg, vg, mask, *, scale=None):
    """Masked softmax over gathered tiles. Shapes as kernels.sufa."""
    bh, t, d = q.shape
    _, n_qt, keep, bc, _ = kg.shape
    bq = t // n_qt
    scale = scale or (1.0 / math.sqrt(d))
    qt = q.reshape(bh, n_qt, bq, d).astype(jnp.float32)
    sc = jnp.einsum("bqtd,bqkcd->bqtkc", qt, kg.astype(jnp.float32)) * scale
    sc = jnp.where(jnp.moveaxis(mask, 3, 2) != 0, sc, NEG_INF)
    sc = sc.reshape(bh, n_qt, bq, keep * bc)
    m = sc.max(axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    p = jnp.where(sc <= NEG_INF / 2, 0.0, p)
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    vflat = vg.reshape(bh, n_qt, keep * bc, d).astype(jnp.float32)
    o = jnp.einsum("bqtc,bqcd->bqtd", p / l, vflat)
    return o.reshape(bh, t, d).astype(q.dtype)


def dlzs_block_ref(q, k, *, causal=True, scale=None, block_q=128,
                   block_kv=128):
    """Predicted block maxima via the float-domain pow2 quantizer."""
    from repro.core.dlzs import pow2_quantize

    bh, t, d = q.shape
    s = k.shape[1]
    scale = scale or (1.0 / math.sqrt(d))
    block_q = min(block_q, t)
    block_kv = min(block_kv, s)
    sc = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                    pow2_quantize(k).astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(s)[None, :] <= jnp.arange(t)[:, None] + (s - t)
        sc = jnp.where(mask, sc, NEG_INF)
    n_qt, n_kt = t // block_q, s // block_kv
    sc = sc.reshape(bh, n_qt, block_q, n_kt, block_kv)
    return sc.max(axis=(2, 4))
