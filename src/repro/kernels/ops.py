"""jit'd public wrappers for the Pallas kernels + the fused STAR pipeline.

``star_attention_fused`` chains the three stages kernel-side:
  dlzs_block_scores (fused predict+tile-max, VMEM-resident Â)
  -> jax.lax.top_k over the block-max matrix (SADS tile selection, desc)
  -> XLA gather of the selected KV tiles
  -> sufa_attention (descend-updating block-sparse flash).
Interpret mode executes the kernel bodies on CPU for validation; on TPU the
same calls lower to Mosaic.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.dlzs import dlzs_block_scores
from repro.kernels.flash import flash_attention
from repro.kernels.sufa import sufa_attention

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash(q, k, v, *, causal=True, block_q=128, block_kv=128,
          interpret=True):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_kv=block_kv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("strict", "interpret"))
def sufa(q, kg, vg, mask, *, strict=False, interpret=True):
    return sufa_attention(q, kg, vg, mask, strict=strict,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def dlzs_blockmax(q, k, *, causal=True, block_q=128, block_kv=128,
                  interpret=True):
    return dlzs_block_scores(q, k, causal=causal, block_q=block_q,
                             block_kv=block_kv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_kv", "keep", "strict", "interpret"))
def star_attention_fused(q, k, v, *, keep: int, causal=True, block_q=128,
                         block_kv=128, radius=5.0, strict=False,
                         interpret=True):
    """Full kernel-side STAR pipeline. q/k/v [BH, T|S, d] -> [BH, T, d]."""
    bh, t, d = q.shape
    s = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, t)
    block_kv = min(block_kv, s)
    n_qt, n_kt = t // block_q, s // block_kv
    keep = min(keep, n_kt)

    # Stage 1+2a (kernel): predicted per-tile maxima, Â stays in VMEM.
    bmax = dlzs_block_scores(q, k, causal=causal, block_q=block_q,
                             block_kv=block_kv, interpret=interpret)
    # Stage 2b: SADS tile top-k (desc) + sphere pruning on the tiny matrix.
    vals, idx = jax.lax.top_k(bmax, keep)             # [BH, n_qt, keep]
    valid = (vals > NEG_INF / 2) & (vals >= vals[..., :1] - radius)

    # Gather the selected tiles (XLA dynamic-slice fan-in to the kernel).
    kt = k.reshape(bh, n_kt, block_kv, d)
    vt = v.reshape(bh, n_kt, block_kv, d)
    take = lambda tiles: jnp.take_along_axis(
        tiles[:, None], idx[..., None, None], axis=2)  # [BH,n_qt,keep,Bc,d]
    kg, vg = take(kt), take(vt)

    # in-tile causal mask for the selected tiles
    q_pos = (jnp.arange(t) + (s - t)).reshape(n_qt, block_q)
    kv_pos = idx[..., None] * block_kv + jnp.arange(block_kv)
    mask = jnp.broadcast_to(valid[..., None, None],
                            (bh, n_qt, keep, block_q, block_kv))
    if causal:
        causal_m = (kv_pos[:, :, :, None, :]
                    <= q_pos[None, :, None, :, None])
        mask = mask & causal_m

    # Stage 3 (kernel): descend-updating block-sparse flash.
    return sufa_attention(q, kg, vg, mask, scale=scale, strict=strict,
                          interpret=interpret)
