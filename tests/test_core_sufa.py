"""SU-FA tests: strict scan == oracle, fast path bounded, gathered == oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sads, sufa
from repro.core.star_attention import STARConfig, dense_attention

jax.config.update("jax_enable_x64", False)


def _setup(t=256, s=512, d=64, seed=0, peaked=True):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(keys[0], (t, d), jnp.float32)
    k = jax.random.normal(keys[1], (s, d), jnp.float32)
    v = jax.random.normal(keys[2], (s, d), jnp.float32)
    if peaked:  # attention-like: a few dominant keys (paper Type I/II)
        k = k.at[: s // 16].mul(3.0)
    return q, k, v


@pytest.mark.parametrize("keep", [1, 2, 4])
def test_strict_scan_matches_masked_oracle(keep):
    q, k, v = _setup()
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = (q @ k.T) * scale
    sel = sads.sads_select_blocks(scores, 64, 64, keep=keep, radius=1e9)
    out = sufa.sufa_scan(q, k, v, sel, scale=scale, block_q=64, block_kv=64,
                         strict=True)
    mask = sufa.selection_to_mask(sel, q.shape[0], k.shape[0], 64, 64)
    ref = sufa.masked_attention_ref(q, k, v, mask, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gathered_matches_masked_oracle():
    q, k, v = _setup(seed=1)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = (q @ k.T) * scale
    sel = sads.sads_select_blocks(scores, 64, 64, keep=3, radius=1e9)
    out = sufa.sufa_gathered(q, k, v, sel, scale=scale, block_q=64,
                             block_kv=64)
    mask = sufa.selection_to_mask(sel, q.shape[0], k.shape[0], 64, 64)
    ref = sufa.masked_attention_ref(q, k, v, mask, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_fast_path_close_when_sorted():
    """Descend updating (no rescale) must track strict closely when selection
    order is correct — the first-visited tile holds the true max."""
    q, k, v = _setup(seed=2)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = (q @ k.T) * scale  # exact prediction -> perfectly sorted tiles
    sel = sads.sads_select_blocks(scores, 64, 64, keep=4, radius=1e9)
    strict = sufa.sufa_scan(q, k, v, sel, scale=scale, block_q=64,
                            block_kv=64, strict=True)
    fast = sufa.sufa_scan(q, k, v, sel, scale=scale, block_q=64,
                          block_kv=64, strict=False)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(strict),
                               rtol=2e-5, atol=2e-5)


def test_fast_path_bounded_under_misprediction():
    """With noisy (DLZS-like) prediction the frozen max can be wrong by the
    prediction error; the output must stay within a small relative error."""
    from repro.core import dlzs
    q, k, v = _setup(seed=3)
    scale = 1.0 / np.sqrt(q.shape[-1])
    s_hat = dlzs.dlzs_scores(q, dlzs.pow2_quantize(k), scale)
    sel = sads.sads_select_blocks(s_hat, 64, 64, keep=4, radius=1e9)
    strict = sufa.sufa_scan(q, k, v, sel, scale=scale, block_q=64,
                            block_kv=64, strict=True)
    fast = sufa.sufa_scan(q, k, v, sel, scale=scale, block_q=64,
                          block_kv=64, strict=False)
    err = np.abs(np.asarray(fast) - np.asarray(strict)).max()
    ref_mag = np.abs(np.asarray(strict)).max()
    assert err / ref_mag < 0.15, f"descend-updating error too large: {err}"


def test_full_selection_equals_dense():
    """keep = all tiles + infinite radius must reproduce dense attention."""
    q, k, v = _setup(seed=4, peaked=False)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = (q @ k.T) * scale
    n_kt = k.shape[0] // 64
    sel = sads.sads_select_blocks(scores, 64, 64, keep=n_kt, radius=1e9)
    out = sufa.sufa_scan(q, k, v, sel, scale=scale, block_q=64, block_kv=64,
                         strict=True)
    ref = dense_attention(q, k, v, causal=False, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_invalid_blocks_are_ignored():
    q, k, v = _setup(seed=5)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = (q @ k.T) * scale
    sel = sads.sads_select_blocks(scores, 64, 64, keep=4, radius=1e9)
    # Invalidate the last two slots; result must equal a 2-block selection.
    sel2 = sads.BlockSelection(sel.block_idx,
                               sel.block_valid.at[:, 2:].set(False),
                               sel.block_max)
    out = sufa.sufa_scan(q, k, v, sel2, scale=scale, block_q=64, block_kv=64,
                         strict=True)
    sel_ref = sads.sads_select_blocks(scores, 64, 64, keep=2, radius=1e9)
    ref = sufa.sufa_scan(q, k, v, sel_ref, scale=scale, block_q=64,
                         block_kv=64, strict=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_elem_mask_scan_vs_gathered():
    q, k, v = _setup(seed=6)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = (q @ k.T) * scale
    sel = sads.sads_select_blocks(scores, 64, 64, keep=4, radius=1e9)
    emask = jax.random.bernoulli(jax.random.PRNGKey(7), 0.8,
                                 (4, 4, 64, 64))
    # guarantee every row keeps at least one element in its best block
    emask = emask.at[:, 0, :, 0].set(True)
    a = sufa.sufa_scan(q, k, v, sel, scale=scale, block_q=64, block_kv=64,
                       strict=True, elem_mask=emask)
    b = sufa.sufa_gathered(q, k, v, sel, scale=scale, block_q=64,
                           block_kv=64, elem_mask=emask)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
