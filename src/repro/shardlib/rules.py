"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names ("batch", "heads",
"mlp", ...); a per-run rule table maps logical names onto the physical mesh
axes ("pod", "data", "model"). The same model definition then runs unmodified
on the single-pod (16,16) mesh, the multi-pod (2,16,16) mesh, a 1x1 test mesh,
or no mesh at all (plain CPU unit tests — constraints become no-ops).

Rules are held in a context (``with axis_rules(mesh, rules): ...``) so that
layer code can call ``shd(x, "batch", "seq", "embed")`` without threading a
mesh object through every signature.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax.shard_map graduated from jax.experimental between 0.4.x and 0.6; the
# experimental module is gone in newer releases, the top-level name absent in
# older ones. All repo call sites import shard_map/pvary from here. On the
# 0.4.x fallback, check_rep is disabled: the old replication checker has no
# notion of the varying-manual-axes (pvary) annotations the call sites use.
try:
    shard_map = jax.shard_map
except AttributeError:                                  # JAX <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, **kwargs):
        kwargs.setdefault("check_rep", False)
        return _shard_map_exp(f, **kwargs)

try:
    pvary = jax.lax.pvary
except AttributeError:                                  # JAX <= 0.4.x
    def pvary(x, axis_names):
        """No-op: pre-vma JAX does not track varying manual axes."""
        del axis_names
        return x

AxisVal = Union[None, str, tuple]


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Sequence[str]) -> "jax.sharding.AbstractMesh":
    """Version-portable ``AbstractMesh`` construction.

    Newer JAX takes ``(axis_sizes, axis_names)``; 0.4.x takes a single
    ``shape_tuple`` of ``(name, size)`` pairs. Passing the new form to the
    old constructor raises TypeError ('int' object is not iterable), which
    we catch and translate.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:                                   # JAX <= 0.4.x
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))

# Default logical -> physical mapping for the production meshes.
DEFAULT_RULES: dict[str, AxisVal] = {
    "batch": ("pod", "data"),      # data parallel (hierarchical over pods)
    "seq": None,                   # sequence kept local by default
    "seq_shard": ("pod", "data"),  # explicit sequence parallelism (long ctx)
    "act_seq": "model",            # residual-stream sequence dim (Megatron
    #                                SP: activations sharded across TP ranks)
    "embed": None,                 # d_model replicated (activations)
    "embed_w": ("pod", "data"),    # weight contracting dim — FSDP/ZeRO-3:
    #                                2-D (data x model) weight sharding
    "mlp": "model",                # FFN hidden — tensor parallel
    "heads": "model",              # attention query heads — tensor parallel
    "kv_heads": "model",           # GQA KV heads when divisible by TP degree
    #                                (shape check auto-drops -> replicated)
    "head_dim": None,
    "qkv": None,
    "vocab": "model",              # output-head vocab — tensor parallel
    "embed_tp": "model",           # embedding-table hidden dim — TP
    "experts": "data",             # expert parallelism (MoE dispatch axis)
    "expert_mlp": "model",         # TP inside each expert
    "layers": None,                # scan-stacked layer dim
    "conv": None,
    "state": None,                 # SSM / mLSTM recurrent state feature dim
    "heads_ssm": "model",          # SSM heads — tensor parallel
    "kv_seq": None,                # KV-cache sequence dim (decode: may shard)
}

_CTX = threading.local()


class _RuleContext:
    def __init__(self, mesh: Optional[Mesh], rules: Mapping[str, AxisVal]):
        self.mesh = mesh
        self.rules = dict(rules)


def _get() -> Optional[_RuleContext]:
    return getattr(_CTX, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh],
               rules: Optional[Mapping[str, AxisVal]] = None,
               **overrides: AxisVal):
    """Activate a mesh + logical-rule table for the enclosed region."""
    merged = dict(DEFAULT_RULES if rules is None else rules)
    merged.update(overrides)
    prev = _get()
    _CTX.ctx = _RuleContext(mesh, merged)
    try:
        yield _CTX.ctx
    finally:
        _CTX.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = _get()
    return ctx.mesh if ctx else None


def current_rules() -> Mapping[str, AxisVal]:
    ctx = _get()
    return ctx.rules if ctx else DEFAULT_RULES


def _resolve_one(logical: Optional[str], mesh: Mesh,
                 rules: Mapping[str, AxisVal]):
    """Logical name -> mesh axis (or tuple), dropping axes absent from mesh."""
    if logical is None:
        return None
    val = rules.get(logical, None)
    if val is None:
        return None
    if isinstance(val, str):
        val = (val,)
    present = tuple(a for a in val if a in mesh.axis_names)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def logical_spec(logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> P:
    """Build a PartitionSpec from logical axis names using the active rules.

    If ``shape`` is given, any mapping whose mesh-axis product does not divide
    the dimension is dropped (replicate) — keeps shard_map/memory estimates
    honest instead of relying on GSPMD padding.
    """
    ctx = _get()
    if ctx is None or ctx.mesh is None:
        return P()
    mesh = ctx.mesh
    entries = []
    used: set = set()
    for i, name in enumerate(logical):
        ax = _resolve_one(name, mesh, ctx.rules)
        if ax is not None:
            # a mesh axis may appear at most once per spec: first dim wins
            axes = tuple(a for a in ((ax,) if isinstance(ax, str) else ax)
                         if a not in used)
            ax = None if not axes else (axes if len(axes) > 1 else axes[0])
        if ax is not None and shape is not None:
            axes = (ax,) if isinstance(ax, str) else ax
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if shape[i] % size != 0:
                ax = None
        if ax is not None:
            used.update((ax,) if isinstance(ax, str) else ax)
        entries.append(ax)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shd(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint (no-op outside a rules context)."""
    ctx = _get()
    if ctx is None or ctx.mesh is None:
        return x
    spec = logical_spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def batch_axes() -> tuple:
    """The physical mesh axes backing the logical 'batch' axis (for psums)."""
    ctx = _get()
    if ctx is None or ctx.mesh is None:
        return ()
    ax = _resolve_one("batch", ctx.mesh, ctx.rules)
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


def mesh_axis(logical: str):
    """Resolve one logical name to a mesh axis name (or None)."""
    ctx = _get()
    if ctx is None or ctx.mesh is None:
        return None
    return _resolve_one(logical, ctx.mesh, ctx.rules)


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes backing a logical axis (1 if unmapped)."""
    ctx = _get()
    if ctx is None or ctx.mesh is None:
        return 1
    ax = _resolve_one(logical, ctx.mesh, ctx.rules)
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else ax
    size = 1
    for a in axes:
        size *= ctx.mesh.shape[a]
    return size


def tree_shardings(mesh: Mesh, axes_tree, rules=None):
    """Map a tree of logical-axes tuples to a tree of NamedShardings."""
    rules = dict(DEFAULT_RULES if rules is None else rules)

    def one(axes):
        with axis_rules(mesh, rules):
            return NamedSharding(mesh, logical_spec(axes))

    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def tree_shardings_shaped(mesh: Mesh, axes_tree, shape_tree, rules=None):
    """Like tree_shardings but drops non-divisible mappings using shapes."""
    rules = dict(DEFAULT_RULES if rules is None else rules)

    def one(axes, sds):
        with axis_rules(mesh, rules):
            return NamedSharding(mesh, logical_spec(axes, sds.shape))

    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
