"""Subprocess program: MoE EP (all_to_all) == single-device MoE on a
(pod=2, data=2, model=2) mesh, including gradients."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe
from repro.shardlib import rules as shr

cfg = moe.MoECfg(d_model=32, d_ff=64, n_experts=4, top_k=2,
                 capacity_factor=8.0,  # no drops -> exact comparison
                 token_chunk=1024, dtype=jnp.float32)
params = moe.init(jax.random.PRNGKey(0), cfg, ep_hint=2)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)

# reference: no mesh (single shard)
ref_out, ref_aux = moe.apply(params, cfg, x)


def loss(p, x_):
    y, aux = moe.apply(p, cfg, x_)
    return (y.astype(jnp.float32) ** 2).sum() + aux


ref_grads = jax.grad(loss)(params, x)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
with shr.axis_rules(mesh):
    out, aux = jax.jit(lambda p, x_: moe.apply(p, cfg, x_))(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)
    # aux is computed per data shard then averaged (mean of per-shard
    # losses != global loss; standard practice) — statistical tolerance.
    np.testing.assert_allclose(float(aux), float(ref_aux), rtol=0.1)
    print("moe forward parity: OK")

    grads = jax.jit(jax.grad(loss))(params, x)
    for key in ("w1", "w2", "w3", "wg"):
        np.testing.assert_allclose(
            np.asarray(grads[key], np.float32),
            np.asarray(ref_grads[key], np.float32), rtol=3e-3, atol=3e-3,
            err_msg=key)
    print("moe gradient parity: OK")

print("ALL_OK")
