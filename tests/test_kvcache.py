"""Paged KV-cache subsystem: pool mechanics, paged attention numerics,
DLZS retention policy, and paged-engine specifics (prefix-sharing
internals, swap occupancy, priority preemption). The engine-level
parity/pressure/shed scenarios every backend must pass moved to the
shared conformance suite in tests/test_engine_core.py."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kvcache import (SCRATCH, PagePool, PagedAllocator, PoolExhausted,
                           bucketing, metrics)
from repro.kvcache import paged_attention as pa
from repro.models import lm
from repro.serving import (PagedEngineCfg, PagedServingEngine, Request,
                           SchedulerCfg)

jax.config.update("jax_enable_x64", False)


# -- page pool ----------------------------------------------------------------

def test_pool_alloc_free_refcount():
    pool = PagePool(6, page_size=4)          # 5 usable (page 0 = scratch)
    a, b = pool.alloc(), pool.alloc()
    assert a != SCRATCH and b != SCRATCH and a != b
    assert pool.ref(a) == 1
    pool.incref(a)
    assert pool.ref(a) == 2
    pool.decref(a)
    assert pool.ref(a) == 1
    pool.decref(a)                           # unindexed ref-0 page is freed
    assert pool.ref(a) == 0
    assert pool.free_pages() == 4
    for _ in range(4):
        pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()
    st = pool.stats()
    assert st.live == 5 and st.peak_live == 5 and st.free == 0


def test_pool_prefix_share_and_cached_eviction():
    pool = PagePool(5, page_size=4)
    key = (1, 2, 3, 4)
    pid = pool.alloc()
    pool.register(key, pid)
    # sharing: lookup bumps the refcount of the SAME page — no duplicate
    assert pool.lookup(key) == pid
    assert pool.ref(pid) == 2
    assert pool.stats().shared_hits == 1
    # releasing all refs caches (not frees) an indexed page
    pool.decref(pid)
    pool.decref(pid)
    assert pool.evictable() == [pid]
    # a cached page revives through the index
    assert pool.lookup(key) == pid
    assert pool.ref(pid) == 1
    pool.decref(pid)
    pool.evict(pid)
    assert pool.lookup(key) is None          # evicted: index entry gone
    assert pool.stats().evictions == 1


def test_pool_cow_detaches_shared_page():
    pool = PagePool(5, page_size=4)
    pid = pool.alloc()
    pool.register((0, 0, 0, 0), pid)
    pool.lookup((0, 0, 0, 0))                # second reference
    alloc = PagedAllocator(pool)
    pages = [pid]
    src, dst = alloc.ensure_owned(pages, 0)
    assert src == pid and dst != pid
    assert pages[0] == dst
    assert pool.ref(pid) == 1 and pool.ref(dst) == 1
    assert pool.stats().cow_copies == 1
    # private pages are left alone
    assert alloc.ensure_owned(pages, 0) is None


def test_allocator_admit_shares_full_pages_only():
    pool = PagePool(10, page_size=4)
    alloc = PagedAllocator(pool)
    p1, fresh1, sh1 = alloc.admit(list(range(10)))       # 2 full + 1 partial
    assert len(p1) == 3 and sh1 == 0 and fresh1 == p1
    alloc.register_prompt_pages(list(range(10)), p1, fresh1)
    # same 8-token prefix, different tail: the 2 full pages are shared
    prompt2 = list(range(8)) + [99, 98, 97]
    p2, fresh2, sh2 = alloc.admit(prompt2)
    assert sh2 == 2
    assert p2[:2] == p1[:2]                  # NOT duplicated
    assert p2[2] not in p1
    assert pool.ref(p1[0]) == 2


def test_allocator_select_hot_prefers_dlzs_scores():
    pool = PagePool(12, page_size=4)
    alloc = PagedAllocator(pool, recent_pages=1)
    pages = [pool.alloc() for _ in range(6)]
    scores = np.zeros(12)
    scores[pages[1]] = 90.0                  # hottest cold page
    scores[pages[3]] = 80.0
    phys, logical = alloc.select_hot(pages, 3, scores)
    # newest page always kept; two slots left for top-scored cold pages
    assert list(logical) == [1, 3, 5]
    assert list(phys) == [pages[1], pages[3], pages[5]]
    # under capacity: identity mapping, -1 padded
    phys, logical = alloc.select_hot(pages[:2], 4, scores)
    assert list(logical) == [0, 1, -1, -1]
    assert list(phys) == pages[:2] + [-1, -1]


def test_allocator_eviction_lowest_score_first():
    pool = PagePool(4, page_size=4)          # 3 usable
    alloc = PagedAllocator(pool)
    pids = [pool.alloc() for _ in range(3)]
    for i, pid in enumerate(pids):
        pool.register((i,), pid)
        pool.decref(pid)                     # all cached
    scores = np.zeros(4)
    scores[pids[0]], scores[pids[1]], scores[pids[2]] = 5.0, 1.0, 9.0
    got = alloc.extend(scores)               # evicts pids[1] (lowest score)
    assert got == pids[1]
    assert pool.lookup((1,)) is None
    assert pool.lookup((0,)) is not None     # higher-scored pages survive


def test_bucketing():
    assert bucketing.bucket_pages(1, 16) == 1
    assert bucketing.bucket_pages(17, 16) == 2
    assert bucketing.bucket_pages(33, 16, pow2=True) == 4
    assert bucketing.bucket_pages(33, 16, pow2=False) == 3
    padded = bucketing.pad_tokens(np.arange(5), 8)
    assert list(padded) == [0, 1, 2, 3, 4, 0, 0, 0]


def test_chunk_spans():
    # monolithic: one span at the bucketed width
    assert bucketing.chunk_spans(33, 16, None) == [(0, 33, 64)]
    assert bucketing.chunk_spans(33, 16, None, pow2=False) == [(0, 33, 48)]
    # short prompt: chunking never pads beyond the monolithic bucket
    assert bucketing.chunk_spans(8, 16, 4) == [(0, 8, 16)]
    # long prompt: full chunks then a bucketed remainder, page-aligned
    spans = bucketing.chunk_spans(100, 16, 2)
    assert spans == [(0, 32, 32), (32, 64, 32), (64, 96, 32), (96, 100, 16)]
    assert all(s % 16 == 0 for s, _, _ in spans)
    with pytest.raises(ValueError):
        bucketing.chunk_spans(0, 16, 2)
    with pytest.raises(ValueError, match="chunk_pages"):
        bucketing.chunk_spans(100, 16, 0)


def test_budget_tokens_and_pack_budget():
    # page-aligned, and never narrower than the widest single chunk
    assert bucketing.budget_tokens(64, 16, 2) == 64
    assert bucketing.budget_tokens(40, 16, 2) == 48
    # chunk_pages=3: a bucketed final remainder can round up to 4 pages
    assert bucketing.budget_tokens(16, 16, 3) == 64
    # greedy first-fit in priority order
    assert bucketing.pack_budget(
        [("a", [32]), ("b", [32]), ("c", [32])], 64) == [("a", 1),
                                                         ("b", 1)]
    # the head candidate always advances, even alone over budget
    assert bucketing.pack_budget(
        [("a", [128]), ("b", [16])], 64) == [("a", 1)]
    # packing stops at the first non-fit: priority order is never bypassed
    assert bucketing.pack_budget(
        [("a", [32]), ("b", [64]), ("c", [16])], 64) == [("a", 1)]
    # leftover budget deepens packed sequences round-robin (consecutive
    # chunks merge into one varlen span)
    assert bucketing.pack_budget(
        [("a", [16, 16, 16]), ("b", [16])], 64) == [("a", 3), ("b", 1)]
    assert bucketing.pack_budget([], 64) == []


def test_bucket_count():
    assert bucketing.bucket_count(0) == 1
    assert bucketing.bucket_count(3) == 4
    assert bucketing.bucket_count(4) == 4
    assert bucketing.bucket_count(5, pow2=False) == 5


def test_allocator_admit_chunk_incremental_sharing():
    pool = PagePool(12, page_size=4)
    alloc = PagedAllocator(pool)
    prompt = list(range(10))                     # 2 full + 1 partial page
    p1, f1, _, _ = alloc.admit_chunk(prompt, 0, 2, sharing=True)
    alloc.register_prompt_pages(prompt, p1, f1, 0)
    p2, f2, _, _ = alloc.admit_chunk(prompt, 2, 1, sharing=False)
    alloc.register_prompt_pages(prompt, p2, f2, 2)
    # a second admission of the same prompt shares chunk-by-chunk
    q1, fr1, sh1, sharing = alloc.admit_chunk(prompt, 0, 2, sharing=True)
    assert q1 == p1 and sh1 == 2 and not fr1 and sharing
    q2, fr2, sh2, sharing = alloc.admit_chunk(prompt, 2, 1, sharing=sharing)
    assert sh2 == 0 and len(fr2) == 1 and not sharing
    assert q2[0] not in p1 + p2                  # partial page never shared


# -- paged attention numerics -------------------------------------------------

def _paged_inputs(seed=0, B=2, nh=4, nkv=2, d=8, P=9, page=4, W=3):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, nh, d), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, nkv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, nkv, d), jnp.float32)
    phys = jnp.array([[1, 4, 2], [5, 3, -1]], jnp.int32)
    logical = jnp.array([[0, 1, 2], [0, 1, -1]], jnp.int32)
    kv_len = jnp.array([10, 7], jnp.int32)
    return q, kp, vp, phys, logical, kv_len, nkv, page


def test_paged_gather_decode_matches_dense_oracle():
    q, kp, vp, phys, logical, kv_len, nkv, page = _paged_inputs()
    out = pa.paged_gather_decode(q, kp, vp, phys, logical, kv_len, n_kv=nkv)
    B, nh, d = q.shape
    rep = nh // nkv
    for b in range(B):
        rows_k = np.concatenate(
            [np.asarray(kp[int(p)]) for p, l in zip(phys[b], logical[b])
             if int(l) >= 0], axis=0)[:int(kv_len[b])]
        rows_v = np.concatenate(
            [np.asarray(vp[int(p)]) for p, l in zip(phys[b], logical[b])
             if int(l) >= 0], axis=0)[:int(kv_len[b])]
        for h in range(nh):
            g = h // rep
            sc = rows_k[:, g] @ np.asarray(q[b, h]) / np.sqrt(d)
            p_ = np.exp(sc - sc.max())
            p_ /= p_.sum()
            np.testing.assert_allclose(np.asarray(out[b, h]),
                                       p_ @ rows_v[:, g],
                                       rtol=1e-5, atol=1e-5)


def test_paged_pallas_kernel_matches_fallback():
    q, kp, vp, phys, logical, kv_len, nkv, _ = _paged_inputs(seed=3)
    o_xla = pa.paged_decode(q, kp, vp, phys, logical, kv_len, n_kv=nkv,
                            backend="xla")
    o_pl = pa.paged_decode(q, kp, vp, phys, logical, kv_len, n_kv=nkv,
                           backend="pallas")
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_pl),
                               rtol=1e-5, atol=1e-5)


def test_page_scores_reduce_lz_codes():
    from repro.core import dlzs
    k = jnp.zeros((2, 5, 4, 3, 8), jnp.bfloat16)     # [L,P,page,nkv,dh]
    k = k.at[1, 2, 0, 0, 0].set(64.0)                # exponent 6 in page 2
    k = k.at[0, 4, 1, 2, 3].set(0.25)                # exponent -2 in page 4
    tree = {"b0": {"attn": {"k": k, "k_lz": dlzs.lz_pack(k)}}}
    s = np.asarray(metrics.page_scores(tree))
    assert s.shape == (5,)
    assert s[2] == 64 + 6 and s[4] == 64 - 2 and s[0] == 0


# -- engine-level ------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_lm():
    cfg = dataclasses.replace(get_smoke_config("olmo_1b"), star=None)
    params = lm.init(jax.random.PRNGKey(1), cfg)
    return cfg, params


def _reqs(cfg, lengths, max_tokens=5):
    return [Request(rid=i, prompt=(np.arange(l, dtype=np.int32) * 7 + i)
                    % cfg.vocab, max_tokens=max_tokens)
            for i, l in enumerate(lengths)]



def test_paged_engine_prefix_sharing_not_duplicated(smoke_lm):
    cfg, params = smoke_lm
    eng = PagedServingEngine(cfg, params, PagedEngineCfg(
        max_batch=2, page_size=16, n_pages=32, hot_pages=4, eos_id=-1))
    shared = np.arange(32, dtype=np.int32)           # 2 full pages
    reqs = [Request(rid=i, prompt=np.concatenate(
                [shared, np.full((4 + i,), 100 + i, np.int32)]),
                    max_tokens=6)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    # two ticks: admission binds both slots; prefill advances one prompt
    # per tick (prefill_per_step=1)
    eng.step()
    eng.step()
    t0, t1 = eng.tables[0], eng.tables[1]
    assert t0[:2] == t1[:2], "shared prefix pages were duplicated"
    assert t0[2] != t1[2]
    assert eng.pool.ref(t0[0]) == 2
    assert eng.pool.stats().shared_hits == 2
    done = eng.run([])
    assert set(done) == {0, 1}
    # both sequences produced tokens despite physically shared prefix pages
    assert all(len(v) == 6 for v in done.values())


def test_paged_engine_per_request_max_len(smoke_lm):
    cfg, params = smoke_lm
    eng = PagedServingEngine(cfg, params, PagedEngineCfg(
        max_batch=2, page_size=16, n_pages=32, hot_pages=4, eos_id=-1))
    reqs = [Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                    max_tokens=20, max_len=12),
            Request(rid=1, prompt=np.arange(6, dtype=np.int32),
                    max_tokens=4)]
    done = eng.run(reqs)
    assert len(done[0]) < 20                 # capped by its own max_len
    assert len(done[1]) == 4
    # a request that cannot ever fit the pool is rejected at submit
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(rid=2, prompt=np.arange(8, dtype=np.int32),
                           max_tokens=31 * 16))
    # max_len <= prompt would break page-reservation accounting: rejected
    with pytest.raises(ValueError, match="no room"):
        eng.submit(Request(rid=3, prompt=np.arange(32, dtype=np.int32),
                           max_tokens=4, max_len=16))






def test_paged_engine_batched_prefill_shares_same_tick_prefixes(smoke_lm):
    """Same-prefix prompts packed into the SAME batched dispatch still
    share their prefix pages (the phase-A2 dedup registers fresh full
    pages before the dispatch), and outputs match the sequential path."""
    cfg, params = smoke_lm
    shared = np.arange(32, dtype=np.int32)            # 2 full pages
    mk = lambda: [Request(rid=i, prompt=np.concatenate(
                      [shared, np.full((4 + 3 * i,), 100 + i, np.int32)]),
                  max_tokens=4) for i in range(4)]
    seq = PagedServingEngine(cfg, params, PagedEngineCfg(
        max_batch=4, page_size=16, n_pages=32, hot_pages=8, eos_id=-1),
        SchedulerCfg(chunk_pages=1))
    want = seq.run(mk())
    bat = PagedServingEngine(cfg, params, PagedEngineCfg(
        max_batch=4, page_size=16, n_pages=32, hot_pages=8, eos_id=-1),
        SchedulerCfg(chunk_pages=1, prefill_tokens=64))
    got = bat.run(mk())
    assert got == want
    # 3 followers x 2 prefix pages shared despite same-tick admission
    assert bat.pool.stats().shared_hits >= 6





def test_paged_swap_stable_occupancy_same_prefix(smoke_lm):
    """Regression (shared-prefix-aware swap): repeated preempt/resume of
    same-prefix traffic must neither re-upload the shared prefix nor grow
    pool occupancy. Pages shared at swap-out keep the victim's reference
    (zero host bytes); parked ref-1 prompt pages revive through the
    prefix index on page-in instead of duplicating."""
    cfg, params = smoke_lm
    eng = PagedServingEngine(cfg, params, PagedEngineCfg(
        max_batch=2, page_size=16, n_pages=32, hot_pages=4, eos_id=-1))
    shared = np.arange(32, dtype=np.int32)       # 2 full prefix pages
    reqs = [Request(rid=i, prompt=np.concatenate(
                [shared, np.full((5 + i,), 90 + i, np.int32)]),
                    max_tokens=16)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    for _ in range(4):                            # both slots decoding
        eng.step()
    assert len(eng._decode_slots()) == 2
    slot = 1
    rid = eng.active[slot].rid
    n_private = sum(1 for pid in eng.tables[slot]
                    if eng.pool.ref(pid) == 1)
    assert n_private > 0                          # tail pages are private
    live0, free0 = eng.pool.live_pages(), eng.pool.free_pages()
    per_page = eng.stats()["bytes_per_page"]
    st = eng.sched.running.pop(slot)
    for cycle in range(3):
        assert eng.exec_preempt(slot, True)
        # only the private (ref-1, non-revivable-by-index... the parked)
        # pages hit the host: the 2 prefix pages are shared with slot 0
        # and stay resident under the victim's kept reference
        assert eng.swap_area.stats().bytes == n_private * per_page
        slot = eng.exec_swap_in(st.req)
        assert slot is not None
        assert eng.pool.live_pages() == live0, f"cycle {cycle}: occupancy"
        assert eng.pool.free_pages() == free0, f"cycle {cycle}: leak"
    eng.sched.running[slot] = st
    done = eng.run([])                            # drain to completion
    assert set(done) == {0, 1}
    assert all(len(v) == 16 for v in done.values())
    assert eng.pool.stats().cow_copies == 0


def test_star_chunk_sparse_prefill_within_tolerance():
    """STAR inside later prefill chunks (satellite of the spatial PR):
    with the ``chunk_sparse`` flag the chunk's queries DLZS-predict over
    the gathered past pages and drop whole pages outside the SADS sphere.
    Pages with uniformly tiny keys are dropped — and the output stays
    within the sphere's error bound of the dense chunk path."""
    import dataclasses as dc

    from repro.core.star_attention import STARConfig
    from repro.models import attention

    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 6)
    nkv, nh, dh, page, wp, c = 2, 4, 16, 8, 4, 8
    acfg = attention.AttentionCfg(
        d_model=64, n_heads=nh, n_kv=nkv, head_dim=dh, q_chunk=64,
        star=STARConfig(block_q=8, block_kv=8, radius=14.0),
        chunk_sparse=True, dtype=jnp.float32)
    params = attention.init(ks[0], acfg)
    # past pool: 3 near-zero pages + 1 dominant page. The sphere keeps
    # only the dominant page, and the dropped mass is bounded by
    # S_past * e^-radius of the total — the tolerance below
    kp = jax.random.normal(ks[1], (6, page, nkv, dh), jnp.float32) * 0.01
    kp = kp.at[4].set(jax.random.normal(ks[2], (page, nkv, dh)) * 20.0)
    vp = jax.random.normal(ks[3], (6, page, nkv, dh), jnp.float32)
    from repro.core import dlzs
    cache = {"k": kp, "v": vp, "k_lz": dlzs.lz_pack(kp)}
    x = jax.random.normal(ks[4], (1, c, 64), jnp.float32)
    positions = (wp * page + jnp.arange(c))[None, :]
    past_phys = jnp.array([[1, 2, 4, 3]], jnp.int32)
    past_logical = jnp.array([[0, 1, 2, 3]], jnp.int32)
    past_len = jnp.array([wp * page], jnp.int32)

    run = lambda a: attention.apply_prefill_chunk(
        params, a, x, positions, cache, past_phys, past_logical,
        past_len)[0]
    dense = run(dc.replace(acfg, star=None, chunk_sparse=False))
    sparse = run(acfg)
    keep_all = run(dc.replace(
        acfg, star=dc.replace(acfg.star, radius=1e9)))
    # an infinite sphere keeps every page: exactly the dense path
    np.testing.assert_allclose(np.asarray(keep_all), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
    # the real radius drops the tiny pages: not identical, but within the
    # sphere's e^-radius relative-mass bound
    assert float(jnp.max(jnp.abs(sparse - dense))) > 1e-7
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               atol=0.02)


def test_paged_engine_priority_preempts_low_first(smoke_lm):
    """Under pressure the low-priority request is the victim; the
    high-priority one is never preempted and still finishes exactly."""
    cfg, params = smoke_lm
    reqs = [Request(rid=0, prompt=(np.arange(16, dtype=np.int32) * 7)
                    % cfg.vocab, max_tokens=20, priority=0),
            Request(rid=1, prompt=(np.arange(17, dtype=np.int32) * 7 + 1)
                    % cfg.vocab, max_tokens=20, priority=5),
            Request(rid=2, prompt=(np.arange(16, dtype=np.int32) * 7 + 2)
                    % cfg.vocab, max_tokens=20, priority=0),
            Request(rid=3, prompt=(np.arange(18, dtype=np.int32) * 7 + 3)
                    % cfg.vocab, max_tokens=20, priority=0)]
    eng = PagedServingEngine(cfg, params, PagedEngineCfg(
        max_batch=4, page_size=16, n_pages=9, hot_pages=4, eos_id=-1),
        SchedulerCfg(chunk_pages=1, swap=True))
    victims = []
    orig = eng.exec_preempt
    def spy(slot, swap):
        victims.append(eng.active[slot].rid)
        return orig(slot, swap)
    eng.exec_preempt = spy
    done = eng.run(reqs, max_steps=500)
    assert set(done) == {0, 1, 2, 3}
    assert all(len(v) == 20 for v in done.values())
    assert victims and 1 not in victims          # high priority never evicted
