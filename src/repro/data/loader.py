"""Sharded, prefetching data loader.

Builds globally-sharded jax.Arrays from per-host numpy shards
(``jax.make_array_from_process_local_data`` when multi-host; plain
device_put on a single host) and overlaps host-side batch construction with
device compute via a background prefetch thread (depth-2 queue — the
standard input-pipeline overlap trick).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class ShardedLoader:
    def __init__(self, dataset, sharding, *, start_step: int = 0,
                 prefetch: int = 2):
        self.dataset = dataset
        self.sharding = sharding
        self.step = start_step
        self.prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _build(self, step: int):
        batch = self.dataset.batch(step)
        return {k: jax.device_put(v, self.sharding[k])
                for k, v in batch.items()}

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._build(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def start(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def __iter__(self) -> Iterator:
        self.start()
        while True:
            step, batch = self._q.get()
            self.step = step + 1
            yield step, batch

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    def seek(self, step: int):
        """Restart-safe repositioning (checkpoint restore)."""
        self.stop()
        self._q = queue.Queue(maxsize=self.prefetch)
        self._stop = threading.Event()
        self.step = step
        return self
