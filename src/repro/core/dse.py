"""Design-space exploration for the SADS sub-segment size (paper Appendix A).

The segment granularity S_i trades sorting complexity against SU-FA overhead:
smaller segments cut comparisons (O(S·S·k·rho/n)) but fragment the formal
stage (more tiles -> more per-tile bookkeeping and sync); larger segments do
the opposite. The paper's DSE minimizes J = alpha·C_sort + beta·C_exp with
per-model alpha/beta (e.g. 0.24/0.31 for BERT, 0.58/0.63 for LLaMA).

We reproduce that objective exactly over the equivalent-add op model and grid
search candidate segment sizes (which double as the Pallas kernel's KV block
size, so candidates are multiples of the 128-lane TPU tile).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import opcount

# Paper §VI-B per-model DSE coefficients (alpha: sort weight, beta: exp weight).
PAPER_COEFFS = {
    "bert": (0.24, 0.31),
    "vit": (0.2, 0.24),
    "gpt2": (0.4, 0.42),
    "bloom": (0.53, 0.56),
    "llama": (0.58, 0.63),
}


@dataclasses.dataclass(frozen=True)
class DSEResult:
    block_kv: int          # chosen segment size (= kernel KV tile)
    n_segments: int
    objective: float
    table: tuple           # ((block_kv, J), ...) full sweep for reporting


def segment_dse(seq_len: int, *, t: int = 128, d: int = 128,
                k_ratio: float = 0.2, rho: float = 0.4,
                alpha: float = 0.5, beta: float = 0.5,
                candidates: Sequence[int] = (128, 256, 512, 1024, 2048),
                strict: bool = False) -> DSEResult:
    """Minimize J(n) = alpha·sort_cost + beta·formal_cost over segment sizes."""
    rows = []
    for bc in candidates:
        if seq_len % bc or seq_len // bc < 1:
            continue
        n = seq_len // bc
        if (seq_len * k_ratio) < n:  # need >= 1 kept element per segment
            continue
        sort_cost = opcount.sads_ops(t, seq_len, k_ratio, n, rho).equivalent_adds
        formal = opcount.sufa_ops(t, seq_len, d, bc, k_ratio, strict)
        # beta weights the non-matmul (exp-dominated) overhead specifically.
        exp_cost = opcount.OpCount(exp=formal.exp, cmp=formal.cmp,
                                   mul=formal.mul).equivalent_adds
        j = alpha * sort_cost + beta * exp_cost
        rows.append((bc, j))
    if not rows:
        raise ValueError(f"no feasible segment size for S={seq_len}")
    best = min(rows, key=lambda r: r[1])
    return DSEResult(block_kv=best[0], n_segments=seq_len // best[0],
                     objective=best[1], table=tuple(rows))


def dse_for_model(model: str, seq_len: int, **kw) -> DSEResult:
    alpha, beta = PAPER_COEFFS.get(model, (0.5, 0.5))
    return segment_dse(seq_len, alpha=alpha, beta=beta, **kw)
