"""OLMoE-1B-7B [moe] — 16L d_model=2048 16H (kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8.  [arXiv:2409.02060; hf]"""

from repro.core.star_attention import STARConfig
from repro.models.lm import BlockCfg, ModelCfg
from repro.models.moe import MoECfg


def config() -> ModelCfg:
    return ModelCfg(
        name="olmoe_1b_7b",
        d_model=2048, n_layers=16, n_heads=16, n_kv=16, d_ff=1024,
        vocab=50304,
        pattern=(BlockCfg("attn", "moe"),),
        norm="rmsnorm", mlp_act="silu", mlp_gated=True,
        moe=MoECfg(d_model=2048, d_ff=1024, n_experts=64, top_k=8),
        star=STARConfig(top_k_ratio=0.2),
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        name="olmoe_smoke",
        d_model=64, n_layers=2, n_heads=4, n_kv=4, d_ff=32, vocab=512,
        pattern=(BlockCfg("attn", "moe"),),
        norm="rmsnorm", mlp_act="silu", mlp_gated=True,
        moe=MoECfg(d_model=64, d_ff=32, n_experts=8, top_k=2,
                   token_chunk=64),
        star=STARConfig(top_k_ratio=0.5, block_q=16, block_kv=16),
        q_chunk=64, seq_loss_chunk=64, vocab_pad_to=64,
    )
