"""Wire format for cross-instance KV page transfer (disaggregation).

The backend-uniform flat-payload swap format — the exact dict every
``EngineCore`` backend produces at ``gather_park``/``exec_preempt`` and
consumes at ``exec_swap_in`` — doubles as the wire format the
``serving.disagg.KVTransfer`` fabric moves between a prefill-tuned and a
decode-tuned instance.  This module pins that contract down as data:
what keys a payload must carry, what invariants tie them together, and
how many bytes a payload costs on the hop.  Both ends validate, so a
drifting backend payload fails loudly at the seam instead of corrupting
the peer's pool.

Payload schema (one dict per request)::

    rows         host tree (or None) — every leaf has the page axis at 1
                 ([L, n_park, page, ...]); fp K/V slabs and, when the
                 int8 cold tier is configured, the quantized mirrors AND
                 their per-page scales ride in the same tree, so the
                 quant tier survives the hop for free
    park         [j] global logical indices of the gathered pages, in
                 rows' page-axis order
    kept         [(j, pid)] device-resident shared pages.  A transfer
                 payload must have kept == [] — physical ids are
                 meaningless on the peer instance
    n_pages      block-table length (park ∪ kept must cover it)
    lookup_toks  token tuple for the peer's prefix re-lookup (None when
                 prefix sharing is off)
    kind         "prefill" | "decode" + the matching progress fields
                 (swap_policy.progress_state / restore_progress)
    scores       optional [float] per-park-page DLZS scores (decode-side
                 hot-set selection warms up before its first own pull)
    register_prefix  optional bool — ask the importer to register
                 uploaded full-prompt pages in its prefix index so later
                 same-prefix imports COW-share instead of re-uploading

The importing engine re-derives quant flags from the uploaded scale rows
(``quant.find_scale``) and recomputes DLZS scores from page content, so
``scores`` is advisory — conservation never depends on it.
"""

from __future__ import annotations

from typing import Optional

import jax

PREFILL_KEYS = ("prompt", "toks", "spans", "chunk", "sharing",
                "suppress_first")
DECODE_KEYS = ("length", "last_token", "budget")
_BASE_KEYS = ("rows", "park", "kept", "n_pages", "lookup_toks", "kind")


def payload_bytes(payload: dict) -> int:
    """Host bytes the payload's row tree carries (the hop's cost)."""
    rows = payload.get("rows")
    if rows is None:
        return 0
    return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(rows))


def validate_payload(payload: dict, *,
                     page_size: Optional[int] = None,
                     transfer: bool = False) -> None:
    """Raise ValueError when ``payload`` violates the wire contract.

    ``transfer=True`` additionally enforces the cross-instance rules:
    no ``kept`` device references (physical ids do not travel) and a
    row tree present whenever pages are parked.
    """
    missing = [k for k in _BASE_KEYS if k not in payload]
    if missing:
        raise ValueError(f"payload missing keys {missing}")
    kind = payload["kind"]
    if kind == "prefill":
        want = PREFILL_KEYS
    elif kind == "decode":
        want = DECODE_KEYS
    else:
        raise ValueError(f"payload kind {kind!r} not in "
                         "('prefill', 'decode')")
    missing = [k for k in want if k not in payload]
    if missing:
        raise ValueError(f"{kind} payload missing keys {missing}")

    park = list(payload["park"])
    kept = list(payload["kept"])
    n_pages = payload["n_pages"]
    covered = set(park) | {j for j, _ in kept}
    if covered != set(range(n_pages)):
        raise ValueError(
            f"park ∪ kept covers {sorted(covered)}, expected exactly "
            f"0..{n_pages - 1}")
    if len(covered) != len(park) + len(kept):
        raise ValueError("park and kept overlap")

    rows = payload["rows"]
    if park and rows is None:
        raise ValueError(f"{len(park)} parked pages but rows is None")
    if rows is not None:
        for leaf in jax.tree.leaves(rows):
            if leaf.ndim < 2 or leaf.shape[1] != len(park):
                raise ValueError(
                    f"rows leaf {leaf.shape} page axis (1) != "
                    f"len(park)={len(park)}")
        if page_size is not None:
            # the K/V slab leaves carry page rows at axis 2; smaller
            # leaves (per-page scales) legitimately have fewer axes
            widths = {leaf.shape[2] for leaf in jax.tree.leaves(rows)
                      if leaf.ndim >= 5}
            if widths and widths != {page_size}:
                raise ValueError(
                    f"rows page width {sorted(widths)} != page_size "
                    f"{page_size}")

    scores = payload.get("scores")
    if scores is not None and len(scores) != len(park):
        raise ValueError(
            f"scores carries {len(scores)} entries for "
            f"{len(park)} parked pages")

    if transfer:
        if kept:
            raise ValueError(
                "transfer payload carries device page ids (kept="
                f"{kept}); physical ids do not travel between pools")


def describe(payload: dict) -> dict:
    """Compact summary for recorder/trace events (no array data)."""
    return {"kind": payload.get("kind"),
            "n_pages": payload.get("n_pages"),
            "parked": len(payload.get("park", ())),
            "kept": len(payload.get("kept", ())),
            "bytes": payload_bytes(payload),
            "scored": payload.get("scores") is not None}
