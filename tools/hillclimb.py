"""§Perf hillclimb driver: run named variants of a dry-run cell and print
the roofline-term deltas vs the recorded baseline.

Usage:
  PYTHONPATH=src python tools/hillclimb.py <arch> <shape> <variant> \
      [key=value ...]        # ModelCfg dataclass overrides
Values are eval'd (so rule_overrides=(("embed_w",None),) works).
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.dryrun import run_cell  # noqa: E402  (sets XLA_FLAGS)

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def show(tag, rl):
    print(f"{tag:34s} compute={rl['compute_s']:.3e}s "
          f"memory={rl['memory_s']:.3e}s coll={rl['collective_s']:.3e}s "
          f"useful={rl['useful_ratio']:.2f} -> {rl['bottleneck']}")


def main():
    arch, shape, variant = sys.argv[1:4]
    overrides = {}
    for kv in sys.argv[4:]:
        k, v = kv.split("=", 1)
        overrides[k] = eval(v)  # noqa: S307 — operator tool

    base_path = RESULTS / f"{arch}__{shape}__pod1.json"
    base = json.loads(base_path.read_text()) if base_path.exists() else None
    rec = run_cell(arch, shape, "pod1", variant=variant, force=True,
                   overrides=overrides or None, star_long=True)
    if base and base["status"] == "ok":
        show("baseline", base["roofline"])
    if rec["status"] == "ok":
        show(f"variant:{variant}", rec["roofline"])
        if base and base["status"] == "ok":
            b, n = base["roofline"], rec["roofline"]
            for term in ("compute_s", "memory_s", "collective_s"):
                if b[term] > 0:
                    print(f"  {term}: {n[term] / b[term] - 1:+.1%}")
    else:
        print("variant failed/skipped:", rec)


if __name__ == "__main__":
    main()
