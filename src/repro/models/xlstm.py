"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar).

mLSTM is a gated linear attention:  C_t = f_t C_{t-1} + i_t v_t k_tᵀ,
n_t = f_t n_{t-1} + i_t k_t,  y_t = C_t q_t / max(|n_tᵀ q_t|, 1) — we reuse
``chunked_linear_attention`` with the normalizer carried as an extra value
column (X = [i·v, i·1]). Exponential input gates are soft-clamped instead of
running the paper's m_t stabilizer (fp32 statistics make it unnecessary at
our scale; noted in DESIGN.md).

sLSTM keeps per-head scalar state with block-diagonal recurrent weights and
is inherently sequential -> lax.scan over time.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.ssm import chunked_linear_attention, linear_attention_step
from repro.shardlib import pvary, shard_map, shd


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    d_model: int
    n_heads: int
    chunk: int = 256
    dtype: jnp.dtype = jnp.bfloat16

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def _clamp_exp(x, lo=-10.0, hi=5.0):
    return jnp.exp(jnp.clip(x, lo, hi))


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: XLSTMCfg):
    ks = jax.random.split(key, 7)
    h, nh, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": common.truncated_normal_init(ks[0], (h, nh * dh), 1.0,
                                           cfg.dtype).reshape(h, nh, dh),
        "wk": common.truncated_normal_init(ks[1], (h, nh * dh), 1.0,
                                           cfg.dtype).reshape(h, nh, dh),
        "wv": common.truncated_normal_init(ks[2], (h, nh * dh), 1.0,
                                           cfg.dtype).reshape(h, nh, dh),
        "wi": common.truncated_normal_init(ks[3], (h, nh), 1.0, jnp.float32),
        "wf": common.truncated_normal_init(ks[4], (h, nh), 1.0, jnp.float32),
        "wog": common.truncated_normal_init(ks[5], (h, h), 1.0, cfg.dtype),
        "wo": common.truncated_normal_init(ks[6], (nh * dh, h), 1.0,
                                           cfg.dtype).reshape(nh, dh, h),
        "norm_scale": jnp.ones((nh, dh), jnp.float32),
    }


def mlstm_axes(cfg: XLSTMCfg):
    return {
        "wq": ("embed_w", "heads_ssm", "head_dim"),
        "wk": ("embed_w", "heads_ssm", "head_dim"),
        "wv": ("embed_w", "heads_ssm", "head_dim"),
        "wi": ("embed_w", "heads_ssm"), "wf": ("embed_w", "heads_ssm"),
        "wog": ("embed_w", "embed"),
        "wo": ("heads_ssm", "head_dim", "embed_w"),
        "norm_scale": ("heads_ssm", "head_dim"),
    }


def _mlstm_gates(params, cfg: XLSTMCfg, x):
    q = jnp.einsum("bsh,hnd->bsnd", x, params["wq"])
    k = jnp.einsum("bsh,hnd->bsnd", x, params["wk"]) \
        / jnp.sqrt(float(cfg.head_dim)).astype(x.dtype)
    v = jnp.einsum("bsh,hnd->bsnd", x, params["wv"])
    i_raw = jnp.einsum("bsh,hn->bsn", x.astype(jnp.float32), params["wi"])
    f_raw = jnp.einsum("bsh,hn->bsn", x.astype(jnp.float32), params["wf"])
    i_gate = _clamp_exp(i_raw)                        # exponential input gate
    log_f = jax.nn.log_sigmoid(f_raw)                 # log decay <= 0
    return q, k, v, i_gate, log_f


def _headnorm(y, scale):
    """Per-head RMS norm of the mLSTM readout (xLSTM's multi-head norm)."""
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + 1e-6) * scale


def mlstm_apply(params, cfg: XLSTMCfg, x, *, make_cache: bool = False):
    """x [B,S,H] -> (y, cache|None). Chunk-parallel over the sequence."""
    bsz, s, _ = x.shape
    nh, dh = cfg.n_heads, cfg.head_dim
    q, k, v, i_gate, log_f = _mlstm_gates(params, cfg, x)
    ones = jnp.ones((bsz, s, nh, 1), jnp.float32)
    x_aug = jnp.concatenate(
        [v.astype(jnp.float32), ones], axis=-1) * i_gate[..., None]
    chunk = min(cfg.chunk, s)
    while s % chunk:
        chunk -= 1
    y_aug, h_final = chunked_linear_attention(
        q.astype(jnp.float32), k.astype(jnp.float32), x_aug, log_f,
        chunk=chunk)
    num, den = y_aug[..., :dh], y_aug[..., dh:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = _headnorm(y, params["norm_scale"])
    og = jax.nn.sigmoid(jnp.einsum("bsh,hg->bsg", x, params["wog"]))
    out = jnp.einsum("bsnd,ndh->bsh", y.astype(x.dtype), params["wo"]) * og
    out = shd(out, "batch", "act_seq", "embed")
    cache = {"state": h_final} if make_cache else None
    return out, cache


def mlstm_decode(params, cfg: XLSTMCfg, x, cache):
    """x [B,1,H] -> (y [B,1,H], new cache). O(1) per step."""
    bsz = x.shape[0]
    nh, dh = cfg.n_heads, cfg.head_dim
    q, k, v, i_gate, log_f = _mlstm_gates(params, cfg, x)
    x_aug = jnp.concatenate(
        [v[:, 0].astype(jnp.float32), jnp.ones((bsz, nh, 1))], -1) \
        * i_gate[:, 0, :, None]
    y_aug, h_new = linear_attention_step(
        q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32), x_aug,
        log_f[:, 0], cache["state"])
    num, den = y_aug[..., :dh], y_aug[..., dh:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = _headnorm(y, params["norm_scale"])[:, None]
    og = jax.nn.sigmoid(jnp.einsum("bsh,hg->bsg", x, params["wog"]))
    out = jnp.einsum("bsnd,ndh->bsh", y.astype(x.dtype), params["wo"]) * og
    return out, {"state": h_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: XLSTMCfg):
    ks = jax.random.split(key, 8)
    h, nh, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    w = lambda i: common.truncated_normal_init(
        ks[i], (h, nh * dh), 1.0, cfg.dtype).reshape(h, nh, dh)
    r = lambda i: common.truncated_normal_init(
        ks[i], (nh * dh, dh), 1.0, jnp.float32).reshape(nh, dh, dh)
    return {
        "wz": w(0), "wi": w(1), "wf": w(2), "wo_gate": w(3),
        "rz": r(4), "ri": r(5), "rf": r(6), "ro": r(7),
        "wout": common.truncated_normal_init(
            jax.random.fold_in(key, 99), (nh * dh, h), 1.0,
            cfg.dtype).reshape(nh, dh, h),
    }


def slstm_axes(cfg: XLSTMCfg):
    per_head = ("heads_ssm", "head_dim")
    return {
        "wz": ("embed_w",) + per_head, "wi": ("embed_w",) + per_head,
        "wf": ("embed_w",) + per_head, "wo_gate": ("embed_w",) + per_head,
        "rz": ("heads_ssm", "head_dim", None),
        "ri": ("heads_ssm", "head_dim", None),
        "rf": ("heads_ssm", "head_dim", None),
        "ro": ("heads_ssm", "head_dim", None),
        "wout": ("heads_ssm", "head_dim", "embed_w"),
    }


def _scan_shardmapped(params, carry, xs):
    """Run the sLSTM time scan per-device via shard_map (see slstm_apply)."""
    from repro.shardlib import rules as shr

    mesh = shr.current_mesh()
    rparams = {k: params[k] for k in ("rz", "ri", "rf", "ro")}

    from jax.sharding import PartitionSpec as P

    bspec = shr.logical_spec(("batch",), (xs[0].shape[1],)) \
        if mesh is not None else P()
    b_ax = bspec[0] if len(bspec) else None
    vary_axes = () if b_ax is None else \
        ((b_ax,) if isinstance(b_ax, str) else tuple(b_ax))

    def local(rp, cr, xs_):
        # pvary FIRST, over exactly the axes the activations vary on: R
        # becomes device-varying there, so the recurrent einsum's transpose
        # needs no per-step psum_invariant — the single psum lands at this
        # pvary's transpose, outside the 4096-step loop (§Perf cell C5).
        rp = jax.tree.map(lambda r: pvary(r, vary_axes), rp)
        return jax.lax.scan(lambda c, g: _slstm_step(rp, c, g), cr, xs_)

    if mesh is None or not vary_axes:
        rparams_local = {k: params[k] for k in ("rz", "ri", "rf", "ro")}
        return jax.lax.scan(
            lambda c, g: _slstm_step(rparams_local, c, g), carry, xs)
    rspec = jax.tree.map(
        lambda r: shr.logical_spec(("heads_ssm", "head_dim", None),
                                   r.shape), rparams)
    state_sp = P(b_ax)
    xs_sp = tuple(P(None, b_ax) for _ in xs)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(rspec, (state_sp,) * 3, xs_sp),
        out_specs=((state_sp,) * 3, P(None, b_ax)))
    return fn(rparams, carry, xs)


def _slstm_step(params, carry, gates_t):
    """One recurrent step. carry = (c, n, h) each [B,nh,dh]."""
    c, n, h = carry
    gz, gi, gf, go = gates_t                    # [B,nh,dh] pre-activations
    rec = lambda r: jnp.einsum("bnd,nde->bne", h, r)
    z = jnp.tanh(gz + rec(params["rz"]))
    i = _clamp_exp(gi + rec(params["ri"]))
    f = jax.nn.sigmoid(gf + rec(params["rf"]))
    o = jax.nn.sigmoid(go + rec(params["ro"]))
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * (c_new / jnp.maximum(jnp.abs(n_new), 1.0))
    return (c_new, n_new, h_new), h_new


def slstm_apply(params, cfg: XLSTMCfg, x, *, make_cache: bool = False,
                carry=None):
    """x [B,S,H] -> (y, cache|None). Sequential scan over time."""
    bsz, s, _ = x.shape
    nh, dh = cfg.n_heads, cfg.head_dim
    pre = {g: jnp.einsum("bsh,hnd->bsnd", x,
                         params[g]).astype(jnp.float32)
           for g in ("wz", "wi", "wf", "wo_gate")}
    if carry is None:
        zero = jnp.zeros((bsz, nh, dh), jnp.float32)
        carry = (zero, zero, zero)
    xs = tuple(jnp.moveaxis(pre[g], 1, 0)
               for g in ("wz", "wi", "wf", "wo_gate"))
    # The time scan runs under shard_map: all per-step math is device-local
    # (batch-sharded), so autodiff's psum for the recurrent R-matrix grads
    # lands ONCE at the layer boundary — GSPMD otherwise emits an all-reduce
    # of dR inside the loop, 4096x per layer (§Perf cell C iteration 3).
    carry, hs = _scan_shardmapped(params, carry, xs)
    hs = jnp.moveaxis(hs, 0, 1)                 # [B,S,nh,dh]
    out = jnp.einsum("bsnd,ndh->bsh", hs.astype(x.dtype), params["wout"])
    out = shd(out, "batch", "act_seq", "embed")
    cache = {"c": carry[0], "n": carry[1], "h": carry[2]} if make_cache \
        else None
    return out, cache


def slstm_decode(params, cfg: XLSTMCfg, x, cache):
    carry = (cache["c"], cache["n"], cache["h"])
    y, new_cache = slstm_apply(params, cfg, x, make_cache=True, carry=carry)
    return y, new_cache
