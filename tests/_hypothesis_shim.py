"""Import-guarded hypothesis: property tests skip cleanly when absent.

Test modules do ``from _hypothesis_shim import hypothesis, st, hnp`` instead
of importing hypothesis directly. When the real package is installed the
names are simply re-exported; when it is missing, ``@hypothesis.given(...)``
becomes a pytest skip marker and the strategy namespaces become inert
stand-ins, so the plain (non-property) tests in the same module still run
on clean environments.
"""

from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st
    try:
        import hypothesis.extra.numpy as hnp
    except ImportError:          # numpy extra not installed
        hnp = None
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert strategy stand-in: attribute access / calls / chaining all
        resolve to itself, so module-level strategy expressions evaluate."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_a, **_k):
            return self

        def map(self, _fn):
            return self

        def filter(self, _fn):
            return self

        def flatmap(self, _fn):
            return self

    st = _Strategy()
    hnp = _Strategy()

    class _HypothesisStub:
        """@given marks the test skipped; @settings is a no-op."""

        @staticmethod
        def given(*_a, **_k):
            return pytest.mark.skip(reason="hypothesis not installed")

        @staticmethod
        def settings(*_a, **_k):
            return lambda fn: fn

        @staticmethod
        def assume(_cond):
            return True

    hypothesis = _HypothesisStub()

__all__ = ["HAVE_HYPOTHESIS", "hypothesis", "st", "hnp"]
