"""FlashAttention-2 Pallas TPU kernel — the paper's formal-compute baseline.

Grid (batch*heads, q_tiles, kv_tiles); the kv dim is the innermost
(sequential on TPU), so the (m, l, o) accumulators live in revisited output
blocks in VMEM across kv steps — the standard TPU flash pattern. Block
shapes are explicit BlockSpecs sized for VMEM (q/k/v tiles of
[block x head_dim], fp32 accumulator [block_q x head_dim]).

This kernel intentionally keeps FA-2's per-tile max refresh + rescale — the
overhead SU-FA (kernels/sufa.py) removes. Validated in interpret mode vs
ref.flash_ref; on a real TPU the same code lowers to Mosaic.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, block_q: int, block_kv: int,
                  q_offset: int = 0):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # [Bq, d]
    k = k_ref[0].astype(jnp.float32)                 # [Bc, d]
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        kv_pos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        s = jnp.where(kv_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[0]                                # [Bq]
    l_prev = l_ref[0]
    # FA-2 line 5-8: per-tile max refresh + accumulator rescale.
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    l_new = l_prev * alpha + p.sum(axis=-1)
    o_new = o_ref[0] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = o_new


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True):
    """q [BH, T, d], k/v [BH, S, d] -> [BH, T, d] (fp32 accumulate)."""
    bh, t, d = q.shape
    s = k.shape[1]
    scale = scale or (1.0 / math.sqrt(d))
    block_q = min(block_q, t)
    block_kv = min(block_kv, s)
    grid = (bh, t // block_q, s // block_kv)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_kv=block_kv,
                               q_offset=s - t)
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, t), jnp.float32),
            jax.ShapeDtypeStruct((bh, t), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
