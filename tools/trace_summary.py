"""Per-phase time table from an exported engine trace.

Run:  PYTHONPATH=src python tools/trace_summary.py TRACE.jsonl [...]

Accepts either export format (``Tracer.export_jsonl`` / ``export_chrome``)
and prints where tick time went: total and per-tick milliseconds in the
admit / prefill / decode phases, swap activity (preempt + swap-in +
shed, nested inside the phases), the host-side remainder, and how much
was first-call compile time. ``tools/smoke_serve.py --trace`` prints the
same table after each traced backend run.
"""

from __future__ import annotations

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.obs import format_table, load_trace, phase_summary  # noqa: E402,F401


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print(__doc__.strip().splitlines()[0])
        print("usage: trace_summary.py TRACE.jsonl [TRACE2.json ...]")
        return 2
    for path in argv:
        events = load_trace(path)
        print(format_table(phase_summary(events),
                           title=pathlib.Path(path).stem))
    return 0


if __name__ == "__main__":
    sys.exit(main())
