"""Chunked gated linear attention core + Mamba (SSD) block.

The recurrence  h_t = a_t · h_{t-1} + B_t · X_tᵀ,   y_t = C_tᵀ h_t
(with a_t a per-head scalar decay in (0, 1]) covers both Mamba-2/SSD selective
SSMs and mLSTM matrix memories. Materializing h for every step costs
O(S·n·p) memory — hopeless at 32k+ — so we use the SSD *chunked* form:
within a chunk the contribution is an attention-like masked matmul
(C Bᵀ ⊙ decay), across chunks a short scan carries the [n, p] state.
Cost O(S·c·(n+p)) compute, O(c²) transient — TPU/MXU friendly.

NOTE (DESIGN.md §2, changed assumptions): Jamba uses Mamba-1 (per-channel
diagonal A). The chunk-parallel form requires per-head scalar decay, so we
implement the Mamba-2/SSD structure — same selective-SSM family, TPU-native.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common
from repro.shardlib import shd


def chunked_linear_attention(c_read, b_write, x_val, log_a, *, chunk: int,
                             h0=None):
    """Run the gated linear-attention recurrence in chunk-parallel form.

    c_read:  [B,S,H,n]  readout vectors (C / queries)
    b_write: [B,S,H,n]  write vectors  (B / keys)
    x_val:   [B,S,H,p]  values (input-gate and dt already folded in)
    log_a:   [B,S,H]    log decay per step, <= 0
    h0:      [B,H,n,p]  incoming state (decode/continuation), optional

    Returns (y [B,S,H,p], h_final [B,H,n,p]); fp32 internally.
    """
    bsz, s, nh, n = c_read.shape
    p = x_val.shape[-1]
    if s % chunk:
        raise ValueError(f"S={s} not divisible by chunk={chunk}")
    nc = s // chunk

    f32 = jnp.float32
    cr = c_read.astype(f32).reshape(bsz, nc, chunk, nh, n)
    bw = b_write.astype(f32).reshape(bsz, nc, chunk, nh, n)
    xv = x_val.astype(f32).reshape(bsz, nc, chunk, nh, p)
    la = log_a.astype(f32).reshape(bsz, nc, chunk, nh)

    if h0 is None:
        h0 = jnp.zeros((bsz, nh, n, p), f32)
    else:
        h0 = h0.astype(f32)

    idx = jnp.arange(chunk)
    tri = idx[:, None] >= idx[None, :]          # τ <= t (lower triangular)

    def step(h, inp):
        crc, bwc, xvc, lac = inp                # [B,chunk,H,*]
        L = jnp.cumsum(lac, axis=1)             # [B,chunk,H] inclusive
        # intra-chunk: G[t,τ] = (C_t·B_τ)·exp(L_t − L_τ), τ <= t
        dots = jnp.einsum("bthn,bshn->bhts", crc, bwc)
        decay = jnp.exp(L[:, :, None, :] - L[:, None, :, :])  # [B,t,s,H]
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        g = dots * jnp.moveaxis(decay, 3, 1)    # [B,H,t,s]
        y_intra = jnp.einsum("bhts,bshp->bthp", g, xvc)
        # inter-chunk: y += exp(L_t) · C_t · h_prev
        y_inter = jnp.einsum("bthn,bhnp->bthp", crc, h) \
            * jnp.exp(L)[..., None]
        # state update: h' = exp(L_T) h + Σ_τ exp(L_T − L_τ) B_τ X_τᵀ
        w = jnp.exp(L[:, -1:, :] - L)           # [B,chunk,H]
        h_new = h * jnp.exp(L[:, -1, :])[:, :, None, None] + \
            jnp.einsum("bthn,bth,bthp->bhnp", bwc, w, xvc)
        return h_new, y_intra + y_inter

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (cr, bw, xv, la))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, nh, p)
    return y, h_final


def linear_attention_step(c_read, b_write, x_val, log_a, h):
    """Single decode step. c/b [B,H,n], x [B,H,p], log_a [B,H], h [B,H,n,p]."""
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    h_new = h.astype(f32) * a + jnp.einsum(
        "bhn,bhp->bhnp", b_write.astype(f32), x_val.astype(f32))
    y = jnp.einsum("bhn,bhnp->bhp", c_read.astype(f32), h_new)
    return y, h_new


# ---------------------------------------------------------------------------
# Mamba (SSD) block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_model: int
    expand: int = 2
    head_dim: int = 64
    d_state: int = 16
    d_conv: int = 4
    chunk: int = 256
    dtype: jnp.dtype = jnp.bfloat16

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init(key, cfg: MambaCfg):
    ks = jax.random.split(key, 8)
    h, di, n, nh = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    return {
        "wx": common.truncated_normal_init(ks[0], (h, di), 1.0, cfg.dtype),
        "wz": common.truncated_normal_init(ks[1], (h, di), 1.0, cfg.dtype),
        "wb": common.truncated_normal_init(ks[2], (h, n), 1.0, cfg.dtype),
        "wc": common.truncated_normal_init(ks[3], (h, n), 1.0, cfg.dtype),
        "wdt": common.truncated_normal_init(ks[4], (h, nh), 1.0, cfg.dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "a_log": jnp.zeros((nh,), jnp.float32),   # A = exp(a_log) > 0
        "d_skip": jnp.ones((nh,), jnp.float32),
        "conv_w": common.truncated_normal_init(ks[5], (cfg.d_conv, di), 3.0,
                                               cfg.dtype),
        "conv_b": jnp.zeros((di,), cfg.dtype),
        "wo": common.truncated_normal_init(ks[6], (di, h), 1.0, cfg.dtype),
    }


def axes(cfg: MambaCfg):
    return {
        "wx": ("embed_w", "mlp"), "wz": ("embed_w", "mlp"),
        "wb": ("embed_w", "state"), "wc": ("embed_w", "state"),
        "wdt": ("embed_w", "heads_ssm"), "dt_bias": ("heads_ssm",),
        "a_log": ("heads_ssm",), "d_skip": ("heads_ssm",),
        "conv_w": ("conv", "mlp"), "conv_b": ("mlp",),
        "wo": ("mlp", "embed_w"),
    }


def _depthwise_conv(x, w, b, state=None):
    """Causal depthwise conv over seq. x [B,S,di], w [K,di] -> [B,S,di].

    If ``state`` [B,K-1,di] is given it is the left context (decode path
    passes S=1); returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # [B, S+K-1, di]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y, new_state


def _gates(params, cfg: MambaCfg, xin):
    """Shared projections. xin [B,S,H] -> conv-x, z, B, C, dt, log_a."""
    x = jnp.einsum("bsh,hd->bsd", xin, params["wx"])
    x = shd(x, "batch", "seq", "mlp")
    z = jnp.einsum("bsh,hd->bsd", xin, params["wz"])
    bmat = jnp.einsum("bsh,hn->bsn", xin, params["wb"]).astype(jnp.float32)
    cmat = jnp.einsum("bsh,hn->bsn", xin, params["wc"]).astype(jnp.float32)
    dt_raw = jnp.einsum("bsh,hn->bsn", xin, params["wdt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + params["dt_bias"])   # [B,S,nh] > 0
    log_a = -dt * jnp.exp(params["a_log"])             # [B,S,nh] <= 0
    return x, z, bmat, cmat, dt, log_a


def apply(params, cfg: MambaCfg, xin, *, make_cache: bool = False):
    """Mamba block over a full sequence. xin [B,S,H] -> (y, cache | None)."""
    bsz, s, _ = xin.shape
    nh, hd, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    x, z, bmat, cmat, dt, log_a = _gates(params, cfg, xin)
    x, conv_state = _depthwise_conv(x, params["conv_w"], params["conv_b"])
    x = jax.nn.silu(x)

    xh = x.reshape(bsz, s, nh, hd).astype(jnp.float32)
    xv = xh * dt[..., None]                            # fold dt into X
    cread = jnp.broadcast_to(cmat[:, :, None, :], (bsz, s, nh, n))
    bwrite = jnp.broadcast_to(bmat[:, :, None, :], (bsz, s, nh, n))
    chunk = min(cfg.chunk, s)
    while s % chunk:
        chunk -= 1
    y, h_final = chunked_linear_attention(cread, bwrite, xv, log_a,
                                          chunk=chunk)
    y = y + xh * params["d_skip"][:, None]             # D skip per head
    y = y.reshape(bsz, s, cfg.d_inner).astype(xin.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,dh->bsh", y, params["wo"])
    out = shd(out, "batch", "act_seq", "embed")
    cache = None
    if make_cache:
        cache = {"conv": conv_state,
                 "state": h_final.astype(jnp.float32)}
    return out, cache


def apply_decode(params, cfg: MambaCfg, xin, cache):
    """Single-token decode. xin [B,1,H] -> (y [B,1,H], new cache)."""
    bsz = xin.shape[0]
    nh, hd, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    x, z, bmat, cmat, dt, log_a = _gates(params, cfg, xin)
    x, conv_state = _depthwise_conv(x, params["conv_w"], params["conv_b"],
                                    state=cache["conv"])
    x = jax.nn.silu(x)
    xh = x.reshape(bsz, nh, hd).astype(jnp.float32)
    xv = xh * dt[:, 0, :, None]
    cread = jnp.broadcast_to(cmat[:, 0, None, :], (bsz, nh, n))
    bwrite = jnp.broadcast_to(bmat[:, 0, None, :], (bsz, nh, n))
    y, h_new = linear_attention_step(cread, bwrite, xv, log_a[:, 0],
                                     cache["state"])
    y = y + xh * params["d_skip"][:, None]
    y = y.reshape(bsz, 1, cfg.d_inner).astype(xin.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,dh->bsh", y, params["wo"])
    return out, {"conv": conv_state, "state": h_new}
