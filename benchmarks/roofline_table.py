"""Paper Table III analog: per-(arch x shape x mesh) roofline terms from the
dry-run artifacts (results/dryrun/*.json). The ASIC rows of Table III have
no TPU analogue; the honest comparison on v5e is the three-term roofline +
useful-FLOP ratio recorded by the dry-run."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run():
    files = sorted(RESULTS.glob("*.json")) if RESULTS.exists() else []
    if not files:
        emit("table3_roofline", 0.0, "no dry-run artifacts; run "
             "python -m repro.launch.dryrun --all first")
        return
    for f in files:
        r = json.loads(f.read_text())
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        emit(f"table3_{f.stem}", rl[f"{rl['bottleneck']}_s"] * 1e6,
             f"bottleneck={rl['bottleneck']} "
             f"compute={rl['compute_s']:.2e}s "
             f"memory={rl['memory_s']:.2e}s "
             f"collective={rl['collective_s']:.2e}s "
             f"useful={rl['useful_ratio']:.2f}")
