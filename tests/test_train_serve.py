"""End-to-end behaviour: fault-tolerant train loop + serving engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import SyntheticLM
from repro.launch import steps as launch_steps
from repro.models import lm
from repro.runtime import TrainLoopCfg, train_loop
from repro.serving import EngineCfg, ServingEngine
from repro.serving.engine import Request

jax.config.update("jax_enable_x64", False)


class _LocalLoader:
    """Loader stub: deterministic batches, no sharding (CPU tests)."""

    def __init__(self, ds):
        self.ds = ds
        self.step = 0

    def __iter__(self):
        while True:
            b = self.ds.batch(self.step)
            s = self.step
            self.step += 1
            yield s, {k: jnp.asarray(v) for k, v in b.items()}

    def seek(self, step):
        self.step = step
        return self

    def stop(self):
        pass


def _setup(tmp_path, fail_at=None, total=12):
    cfg = dataclasses.replace(get_smoke_config("olmo_1b"), star=None)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    step_fn = jax.jit(launch_steps.make_train_step(
        cfg, lr=1e-3, warmup=5, total_steps=200))
    _, opt_init, _, _ = launch_steps.make_optimizer(cfg)
    opt_state = opt_init(params)
    ds = SyntheticLM(vocab=cfg.vocab, seq=32, global_batch=4)
    loop_cfg = TrainLoopCfg(total_steps=total, ckpt_every=5,
                            ckpt_dir=str(tmp_path), log_every=4,
                            fail_at_step=fail_at)
    return cfg, params, opt_state, step_fn, ds, loop_cfg


def test_training_reduces_loss(tmp_path):
    cfg, params, opt, step_fn, ds, loop_cfg = _setup(tmp_path, total=25)
    params, opt, hist = train_loop(step_fn, params, opt, _LocalLoader(ds),
                                   loop_cfg, log_fn=lambda *_: None)
    losses = [l for _, l in hist]
    assert losses[-1] < losses[0] - 0.1, f"no learning: {losses}"


def test_failure_recovery_checkpoint_restart(tmp_path):
    """Kill training mid-run (injected node failure); a fresh loop must
    resume from the committed checkpoint and finish with the same data
    stream (position-keyed batches)."""
    cfg, params, opt, step_fn, ds, loop_cfg = _setup(tmp_path, fail_at=8,
                                                     total=12)
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(step_fn, params, opt, _LocalLoader(ds), loop_cfg,
                   log_fn=lambda *_: None)
    # restart: fresh params (as a new process would init), restore happens
    params2 = lm.init(jax.random.PRNGKey(0), cfg)
    _, opt_init, _, _ = launch_steps.make_optimizer(cfg)
    opt2 = opt_init(params2)
    loop_cfg2 = dataclasses.replace(loop_cfg, fail_at_step=None)
    params2, opt2, hist = train_loop(step_fn, params2, opt2,
                                     _LocalLoader(ds), loop_cfg2,
                                     log_fn=lambda *_: None)
    assert int(opt2["step"]) == 12  # completed all steps post-resume


def test_resume_matches_uninterrupted(tmp_path):
    """Checkpoint-restart must be exact: a run failed+resumed produces the
    same final params as one uninterrupted run."""
    # uninterrupted
    cfg, params, opt, step_fn, ds, loop_cfg = _setup(tmp_path / "a",
                                                     total=10)
    pa, _, _ = train_loop(step_fn, params, opt, _LocalLoader(ds),
                          dataclasses.replace(loop_cfg, ckpt_every=5),
                          log_fn=lambda *_: None)
    # interrupted at 7, resumed (checkpoint at 5)
    cfg, params, opt, step_fn2, ds, loop_cfg = _setup(tmp_path / "b",
                                                      fail_at=7, total=10)
    with pytest.raises(RuntimeError):
        train_loop(step_fn2, params, opt, _LocalLoader(ds), loop_cfg,
                   log_fn=lambda *_: None)
    params2 = lm.init(jax.random.PRNGKey(0), cfg)
    _, opt_init, _, _ = launch_steps.make_optimizer(cfg)
    pb, _, _ = train_loop(step_fn2, params2, opt_init(params2),
                          _LocalLoader(ds),
                          dataclasses.replace(loop_cfg, fail_at_step=None),
                          log_fn=lambda *_: None)
    for la, lb in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=2e-2, atol=2e-2)


# -- serving ------------------------------------------------------------------

def test_engine_continuous_batching():
    cfg = get_smoke_config("olmo_1b")
    params = lm.init(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(cfg, params, EngineCfg(max_batch=2, max_len=64,
                                               eos_id=-1))
    prompts = [np.arange(8, dtype=np.int32) + i for i in range(5)]
    reqs = [Request(rid=i, prompt=p, max_tokens=6)
            for i, p in enumerate(prompts)]
    done = eng.run(reqs)
    assert set(done) == {0, 1, 2, 3, 4}   # 5 requests through 2 slots
    for out in done.values():
        assert len(out) == 6
        assert all(0 <= t < cfg.vocab for t in out)


def test_engine_matches_manual_greedy_decode():
    """Engine output == hand-rolled prefill+decode for a single request."""
    cfg = get_smoke_config("olmo_1b")
    params = lm.init(jax.random.PRNGKey(2), cfg)
    prompt = np.arange(8, dtype=np.int32)

    eng = ServingEngine(cfg, params, EngineCfg(max_batch=2, max_len=64,
                                               eos_id=-1))
    out = eng.run([Request(rid=0, prompt=prompt, max_tokens=5)])[0]

    logits, cache = lm.prefill(params, cfg,
                               {"tokens": jnp.asarray(prompt)[None, :]},
                               cache_len=64)
    want = [int(jnp.argmax(logits[0, :cfg.vocab]))]
    tok = jnp.array([[want[-1]]], jnp.int32)
    for _ in range(4):
        logits, cache = lm.decode_step(params, cfg, tok, cache)
        want.append(int(jnp.argmax(logits[0, :cfg.vocab])))
        tok = jnp.array([[want[-1]]], jnp.int32)
    assert out == want
