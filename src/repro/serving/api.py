"""The serving front door: one ``LLM`` interface over every backend.

``LLM`` is the single entry point ``launch/serve.py``, the benchmarks,
the smoke tests and the examples drive. It wraps any serving engine —
dense slot baseline, paged single-pool, or the sequence-sharded spatial
runtime — behind one surface:

    llm = LLM.from_config(cfg, backend="paged")     # or "dense"/"spatial"
    h = llm.submit(prompt, max_tokens=64, sla="interactive")
    for tok in h:                   # streams tokens, ticking the engine
        ...
    llm.run_until_done()            # or drive tick() yourself
    print(llm.metrics())            # TTFT / tok/s / occupancy / preempts

Layering (docs/serving.md): ``LLM`` owns request ids, submit-time
records and the serve loop; ``EngineCore`` (one shared executor state
machine) owns slots, tables and the swap area; a ``Backend`` owns device
state. The paged/spatial backends default to the batched varlen prefill
with ``prefill_tokens="auto"`` — the scheduler's EMA controller sizes
the per-tick prefill budget from observed tick wall-times.

Observability (docs/observability.md): every record is a full
``obs.RequestTimeline`` (submit → admit → first chunk → TTFT →
per-token → done/preempted). Pass ``telemetry=obs.Telemetry()`` to
``from_config`` (or the constructor) to additionally capture tick-phase
trace spans and the serving metrics registry; the default is the
zero-cost ``NULL_TELEMETRY``.
"""

from __future__ import annotations

import time
from typing import Iterator, Optional

import numpy as np

from repro import obs
from repro.obs import NULL_TELEMETRY
from repro.serving.engine import Request

BACKENDS = ("dense", "paged", "spatial")


class RequestRecord(obs.RequestTimeline):
    """One request's lifecycle record: the ``obs.RequestTimeline`` the
    engine stamps, plus the request itself. ``LLM.records`` maps rid to
    these; handles read tokens and timing through them."""

    __slots__ = ("req",)

    def __init__(self, req: Request, submit_t: float):
        super().__init__(req.rid, sla=req.sla, submit_t=submit_t)
        self.req = req


class RequestHandle:
    """One submitted request: stream its tokens or wait for the result.

    Iterating the handle yields generated tokens as they appear,
    driving ``llm.tick()`` whenever none are buffered — so a plain
    ``for tok in handle`` serves the whole engine (co-resident requests
    included) while streaming this one."""

    def __init__(self, llm: "LLM", rid: int):
        self._llm = llm
        self.rid = rid

    @property
    def _record(self) -> RequestRecord:
        return self._llm.records[self.rid]

    @property
    def tokens(self) -> list[int]:
        """Tokens generated so far."""
        return list(self._record.req.out or ())

    @property
    def done(self) -> bool:
        return self._record.done_t is not None

    @property
    def ttft_s(self) -> Optional[float]:
        return self._record.ttft

    @property
    def outcome(self) -> Optional[str]:
        """Terminal state: "done" | "cancelled" | "expired" | "failed";
        None while in flight."""
        rec = self._record
        return rec.outcome or getattr(rec.req, "finish_reason", None)

    @property
    def timeline(self) -> obs.RequestTimeline:
        """The request's lifecycle timeline (``.epochs()`` for the
        time-sorted event list, ``.tpots`` for inter-token gaps)."""
        return self._record

    def cancel(self, reason: str = "client") -> bool:
        """Terminate this request wherever it is (queued, prefilling,
        decoding, or swapped out); already-terminal requests return
        False. Tokens generated so far stay readable."""
        return self._llm.cancel(self.rid, reason=reason)

    def __iter__(self) -> Iterator[int]:
        sent = 0
        while True:
            out = self._record.req.out or ()
            while sent < len(out):
                yield int(out[sent])
                sent += 1
            if self.done:
                return
            if not self._llm.has_work():     # defensive: nothing can move
                return
            self._llm.tick()

    def result(self, max_steps: int = 100_000) -> list[int]:
        """Drive the engine until this request finishes; returns its
        tokens (other requests keep being served along the way)."""
        steps = 0
        while not self.done and self._llm.has_work() and steps < max_steps:
            self._llm.tick()
            steps += 1
        return self.tokens


class LLM:
    """Front-door serving interface over a constructed engine.

    Use ``LLM.from_config`` to build engine + backend in one call, or
    pass any engine exposing ``submit / step / queue / active``
    (``PagedServingEngine``, ``SpatialServingEngine``, the dense
    ``ServingEngine``)."""

    def __init__(self, engine, telemetry=None):
        self.engine = engine
        if telemetry is not None and hasattr(engine, "attach_telemetry"):
            engine.attach_telemetry(telemetry)
        self.tel = telemetry or getattr(engine, "tel", None) \
            or NULL_TELEMETRY
        self.records: dict[int, RequestRecord] = {}
        self._pending: dict[int, RequestRecord] = {}   # not yet finished:
        #                         the only records a tick has to touch, so
        #                         a long-lived serve loop stays O(active)
        #                         per tick, not O(all-time requests)
        self._next_rid = 0
        # the dense slot engine predates the scheduler protocol: its tick
        # is an explicit admit() + generator-style step()
        self._dense = not hasattr(engine, "sched")

    # -- construction --------------------------------------------------------

    @classmethod
    def from_config(cls, model_cfg, *, backend: str = "paged",
                    params=None, shards: int = 2, engine_cfg=None,
                    sched_cfg=None, rng=None, telemetry=None,
                    audit_cfg=None) -> "LLM":
        """Build params (if not given), the backend engine, and the LLM.

        ``backend`` picks the runtime: ``"dense"`` (slot baseline,
        ``EngineCfg``), ``"paged"`` (single page pool,
        ``PagedEngineCfg``), ``"spatial"`` (sequence-sharded across
        ``shards`` devices, ``SpatialEngineCfg`` — the process must
        already have that many jax devices, see
        ``repro.spatial.ensure_host_devices``). ``engine_cfg`` overrides
        the backend's default config; ``sched_cfg`` the scheduler's
        (default: batched prefill with the ``prefill_tokens="auto"``
        budget controller). ``rng`` seeds both param init and sampling.
        ``telemetry`` (an ``obs.Telemetry``) enables tracing + metrics.
        ``audit_cfg`` (an ``obs.AuditCfg``) tunes the sampled DLZS
        prediction audit of the core engines — it only ever runs with
        telemetry enabled (``AuditCfg(every_ticks=0)`` disables it even
        then).
        """
        import jax

        from repro.models import lm
        from repro.serving.engine import EngineCfg, ServingEngine
        from repro.serving.paged import PagedEngineCfg, PagedServingEngine
        from repro.serving.scheduler import SchedulerCfg

        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}: choose from {BACKENDS}")
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        if params is None:
            params = lm.init(rng, model_cfg)
        if backend == "dense":
            eng = ServingEngine(model_cfg, params,
                                engine_cfg or EngineCfg(), rng=rng)
            return cls(eng, telemetry=telemetry)
        scfg = sched_cfg or SchedulerCfg(prefill_tokens="auto")
        if backend == "paged":
            eng = PagedServingEngine(model_cfg, params,
                                     engine_cfg or PagedEngineCfg(),
                                     scfg, rng=rng)
        else:
            from repro.spatial.engine import (SpatialEngineCfg,
                                              SpatialServingEngine)
            eng = SpatialServingEngine(
                model_cfg, params,
                engine_cfg or SpatialEngineCfg(n_shards=shards),
                scfg, rng=rng)
        if audit_cfg is not None:
            eng.auditor = obs.DlzsAuditor(audit_cfg)
        return cls(eng, telemetry=telemetry)

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, max_tokens: int = 32, *,
               sla: Optional[str] = None, priority: Optional[int] = None,
               max_len: Optional[int] = None, rid: Optional[int] = None,
               deadline_ms: Optional[float] = None,
               ttft_deadline_ms: Optional[float] = None
               ) -> RequestHandle:
        """Queue one request; returns its handle. ``sla`` is the QoS
        input — the scheduler maps it to a priority at submit (an
        explicit ``priority`` wins). ``deadline_ms`` /
        ``ttft_deadline_ms`` bound end-to-end and first-token latency;
        a lapsed budget makes the request terminal with outcome
        "expired" (with ``SchedulerCfg.sla_deadlines`` the SLA class
        fills unset budgets from ``SLA_DEADLINES_MS``)."""
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid + 1)
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_tokens=max_tokens, max_len=max_len,
                      sla=None if priority is not None else sla,
                      priority=priority or 0,
                      deadline_ms=deadline_ms,
                      ttft_deadline_ms=ttft_deadline_ms)
        rec = RequestRecord(req, time.perf_counter())
        if self.tel.enabled:
            # pre-register so the engine's timeline(rid) lookups stamp
            # THIS record (record and timeline are one object)
            self.tel.timelines[rid] = rec
        try:
            # submit before keeping the record: a capacity rejection
            # (ValueError) must not leave a phantom never-finishing
            # record behind in a long-lived server
            self._submit_engine(req)
        except Exception:
            if self.tel.enabled:
                self.tel.timelines.pop(rid, None)
            raise
        self.records[rid] = rec
        self._pending[rid] = rec
        return RequestHandle(self, rid)

    # -- the serve loop ------------------------------------------------------

    # The three engine touch-points below are the subclass seam: the
    # disaggregated router (serving/disagg) overrides them to route
    # submits to a prefill instance, step both instances with a KV
    # handoff in between, and cancel across instances — while tick()'s
    # record stamping and submit()'s rollback discipline stay shared.

    def _submit_engine(self, req: Request) -> None:
        self.engine.submit(req)

    def _cancel_engine(self, rid: int, *, reason: str) -> bool:
        return self.engine.cancel(rid, reason=reason)

    def _step_engines(self) -> list[Request]:
        if self._dense:
            span = self.tel.tracer.span("tick")
            with span:
                self.engine.admit()
                finished = list(self.engine.step() or ())
            finished += self.engine.drain_terminal()
            return finished
        # core engines trace their own tick span inside step() and
        # fold abnormal terminals into the finished list themselves
        return self.engine.step() or []

    def tick(self) -> list[Request]:
        """One engine step; stamps TTFT / completion times."""
        finished = self._step_engines()
        now = time.perf_counter()
        for rec in self._pending.values():
            if rec.first_token_t is None and rec.req.out:
                rec.first_token_t = now
        for fin in finished:
            # cancel() may have closed the record already
            rec = self._pending.pop(fin.rid, None)
            if rec is None:
                continue
            if rec.done_t is None:      # engine telemetry may have stamped
                rec.done_t = now
            rec.n_tokens = len(fin.out or ())
            if rec.outcome is None:
                rec.outcome = getattr(fin, "finish_reason", None) or "done"
        return finished

    def cancel(self, rid: int, *, reason: str = "client") -> bool:
        """Terminate a request by id; closes its record immediately (the
        engine also reports it terminal on the next tick, which is a
        no-op here). Returns False for unknown / already-terminal rids."""
        rec = self._pending.get(rid)
        if rec is None or not self._cancel_engine(rid, reason=reason):
            return False
        self._pending.pop(rid, None)
        if rec.done_t is None:
            rec.done_t = time.perf_counter()
        rec.n_tokens = len(rec.req.out or ())
        if rec.outcome is None:
            rec.outcome = rec.req.finish_reason or "cancelled"
        return True

    def has_work(self) -> bool:
        return bool(self.engine.queue or self.engine.active
                    or getattr(self.engine, "_terminal", ()))

    def run_until_done(self, max_steps: int = 100_000) -> dict[int, list]:
        """Drain every queued request; returns {rid: tokens}."""
        done: dict[int, list] = {}
        steps = 0
        while self.has_work() and steps < max_steps:
            for fin in self.tick():
                done[fin.rid] = fin.out
            steps += 1
        return done

    # kept as the pre-LLM entry-point name some callers still use
    run = run_until_done

    def clear_finished(self) -> None:
        """Drop finished records (typically after ``metrics()``) so a
        persistent server's history does not grow without bound."""
        self.records = {rid: rec for rid, rec in self.records.items()
                        if rec.done_t is None}
        if self.tel.enabled:
            self.tel.timelines = {
                rid: tl for rid, tl in self.tel.timelines.items()
                if tl.done_t is None}

    # -- observability -------------------------------------------------------

    def stats(self) -> dict:
        return self.engine.stats() if hasattr(self.engine, "stats") else {}

    def debug_bundle(self, out_dir: Optional[str] = None) -> str:
        """Dump the serving post-mortem bundle to ``out_dir`` (default
        ``./debug_bundle``): the flight-recorder ring (recorder.jsonl),
        the tick-phase trace (trace.json, Perfetto/chrome format), the
        metrics registry (metrics.json + metrics.prom), the latest page-
        accounting census (accounting.json), retained audit reports
        (audit.json), timeline aggregates (timelines.json) and the
        engine/scheduler config (config.json). Returns the directory.
        Works with telemetry disabled too — the bundle just carries
        empty rings and registries."""
        import dataclasses
        import json
        import os

        out = out_dir or "debug_bundle"
        os.makedirs(out, exist_ok=True)

        def default(o):
            if dataclasses.is_dataclass(o) and not isinstance(o, type):
                return dataclasses.asdict(o)
            if isinstance(o, np.integer):
                return int(o)
            if isinstance(o, np.floating):
                return float(o)
            if isinstance(o, np.ndarray):
                return o.tolist()
            if isinstance(o, (set, frozenset)):
                return sorted(o)
            return repr(o)

        def dump(name, obj):
            with open(os.path.join(out, name), "w") as f:
                json.dump(obj, f, indent=2, default=default)
                f.write("\n")

        eng = self.engine
        with open(os.path.join(out, "recorder.jsonl"), "w") as f:
            f.write(self.tel.recorder.to_jsonl())
        if hasattr(self.tel.tracer, "export_chrome"):
            self.tel.tracer.export_chrome(os.path.join(out, "trace.json"))
        dump("metrics.json", self.tel.metrics.snapshot())
        with open(os.path.join(out, "metrics.prom"), "w") as f:
            f.write(self.tel.metrics.render_prometheus())
        if hasattr(eng, "accounting_snapshot"):
            dump("accounting.json", eng.accounting_snapshot())
        if hasattr(eng, "auditor"):
            dump("audit.json", {
                "cfg": eng.auditor.cfg,
                "runs": eng.auditor.runs,
                "skipped": eng.auditor.skipped,
                "reports": list(eng.auditor.reports)})
        dump("timelines.json", self.tel.aggregate())
        backend = getattr(eng, "backend", eng)
        dump("config.json", {
            "engine": type(eng).__name__,
            "backend": type(backend).__name__,
            "model_cfg": getattr(backend, "cfg", None),
            "engine_cfg": getattr(backend, "pcfg", None),
            "sched_cfg": getattr(getattr(eng, "sched", None), "cfg", None),
            "recorder": {"capacity": self.tel.recorder.capacity,
                         "retained": len(self.tel.recorder),
                         "dropped": self.tel.recorder.dropped},
        })
        return out

    def metrics(self) -> dict:
        """Serving snapshot: request/token counts, wall time, tok/s,
        TTFT/TPOT percentiles (``obs.percentile``, linear interpolation),
        per-SLA TTFT + goodput, pool occupancy and preemption counters —
        everything the launchers and benchmarks report. With live
        telemetry the registry snapshot rides along under ``counters``."""
        st = self.stats()
        occupancy = None
        pool = st.get("pool") or st.get("pools")
        if pool is not None:
            live = pool.live if hasattr(pool, "live") else pool["live"]
            cap = pool.capacity if hasattr(pool, "capacity") \
                else pool["capacity"]
            occupancy = round(live / max(cap, 1), 4)
        sched = st.get("sched")
        out = {
            "occupancy": occupancy,
            "preemptions": getattr(sched, "preemptions", 0),
            "sheds": getattr(sched, "sheds", 0),
            "resumes": getattr(sched, "resumes", 0),
            "engine": st,
        }
        if self.tel.enabled:
            out["counters"] = self.tel.metrics.snapshot()
            if hasattr(self.engine, "dlzs_hot_fraction"):
                # point-in-time snapshot (device sync — metrics() is an
                # endpoint call, never the hot path)
                out["dlzs_hot_fraction"] = self.engine.dlzs_hot_fraction()
        recs = [r for r in self.records.values() if r.done_t is not None]
        if not recs:
            out["requests"] = 0
            return out
        t0 = min(r.submit_t for r in recs)
        t1 = max(r.done_t for r in recs)
        n_tok = sum(len(r.req.out) for r in recs)
        ttfts = [r.ttft for r in recs if r.ttft is not None]
        tpots = [g for r in recs for g in r.tpots]
        if not tpots:
            # telemetry off: no per-token stamps — approximate each
            # request's TPOT by its decode-time mean
            for r in recs:
                n = len(r.req.out or ())
                if n > 1 and r.ttft is not None and r.latency is not None:
                    tpots.append((r.latency - r.ttft) / (n - 1))
        by_sla: dict[str, list] = {}
        for r in recs:
            by_sla.setdefault(r.req.sla or "default", []).append(r)

        def pct_ms(xs, q):
            v = obs.percentile(xs, q)
            return None if v is None else round(1e3 * v, 2)

        per_sla = {}
        for k, v in sorted(by_sla.items()):
            # goodput counts only work that completed within its budgets:
            # tokens of cancelled/expired/failed requests were wasted
            ok = [r for r in v if (r.outcome or "done") == "done"]
            g_ttfts = [r.ttft for r in ok if r.ttft is not None]
            g_tok = sum(len(r.req.out or ()) for r in ok)
            g_span = max(r.done_t for r in v) - min(r.submit_t for r in v)
            outcomes: dict[str, int] = {}
            for r in v:
                o = r.outcome or "done"
                outcomes[o] = outcomes.get(o, 0) + 1
            per_sla[k] = {
                "requests": len(v),
                "outcomes": outcomes,
                "deadline_miss_rate": round(
                    outcomes.get("expired", 0) / len(v), 4),
                "ttft_mean_ms": round(
                    1e3 * sum(g_ttfts) / len(g_ttfts), 1)
                if g_ttfts else None,
                "goodput_tok_s": round(g_tok / g_span, 1)
                if g_span > 0 else None,
            }
        out.update({
            "requests": len(recs),
            "tokens": n_tok,
            "wall_s": round(t1 - t0, 4),
            "tok_s": round(n_tok / max(t1 - t0, 1e-9), 1),
            "ttft_p50_ms": pct_ms(ttfts, 50),
            "ttft_p95_ms": pct_ms(ttfts, 95),
            "ttft_p99_ms": pct_ms(ttfts, 99),
            "ttft_mean_ms": round(1e3 * sum(ttfts) / len(ttfts), 1)
            if ttfts else None,
            "tpot_p50_ms": pct_ms(tpots, 50),
            "tpot_p95_ms": pct_ms(tpots, 95),
            "tpot_p99_ms": pct_ms(tpots, 99),
            "per_sla": per_sla,
        })
        return out
