"""Shared-prefix-aware swap core, shared by the paged and spatial engines.

Both engines preempt the same way — partition the victim's block table
into shared pages that stay live on the device (another sequence still
references them) and uniquely-owned pages that gather to the host
``SwapArea``; on page-in, retry the prefix index before allocating fresh
pages, rolling the whole plan back if the pool cannot supply it. That
core used to live as two drifting copies inside ``serving/paged.py`` and
``spatial/engine.py``; this module is the single implementation, with the
engine-specific parts (which pool owns page ``j``, how device rows are
gathered) injected as callables.

It also hosts the *lazy* swap primitives (``shed_candidates``,
``merge_shed``): under pressure a victim can park only its DLZS-cold
ref-1 pages — exactly the pages the hot-page decode gather was skipping
anyway — and keep decoding on its hot set. A shed table entry becomes the
``SHED`` sentinel; a later full preemption folds the shed payload into
the ordinary swap payload so resume sees one uniform format.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.kvcache.pool import PoolExhausted

SHED = -1   # block-table sentinel: page content parked on the host by a
#             lazy cold-page swap (the physical page was released)


@dataclasses.dataclass
class PrefillProgress:
    """Host-side cursor of a partially prefilled prompt (one shared shape
    for both engines — it is part of the swap payload)."""
    prompt: np.ndarray           # effective prompt (original + replayed)
    toks: Optional[tuple]        # same tokens as int tuple — built once,
    #                              reused for every chunk's prefix-index
    #                              key; None when prefix sharing is off
    spans: list                  # bucketing.chunk_spans output
    chunk: int                   # next span index to run
    sharing: bool                # prefix-share state carried across chunks
    suppress_first: bool         # recompute resume: the final chunk's
    #                              sampled token was already emitted
    pending: Optional[tuple] = None
    # (pages, fresh_globals, n_chunks) allocated for the next n_chunks
    # merged chunks by a batched-prefill attempt that has not computed
    # yet — kept OUT of the block table so a preemption (or a retried
    # batch) can release/reuse them cleanly. ``fresh_globals`` holds
    # GLOBAL logical page indices (engine_core normalizes every backend
    # to this addressing).


def release_pending(pf: Optional[PrefillProgress],
                    release: Callable[[list], None]) -> None:
    """Undo a not-yet-computed chunk allocation before parking/eviction."""
    if pf is not None and pf.pending is not None:
        release(pf.pending[0])
        pf.pending = None


def partition_table(table: Sequence[int], ref_of: Callable[[int], int]
                    ) -> tuple[list, list, list]:
    """Split a block table for parking.

    Returns (kept, park, shed): ``kept`` [(j, pid)] shared pages (ref > 1)
    that keep this sequence's reference on the device; ``park`` [j]
    uniquely-owned resident pages whose contents must gather to the host;
    ``shed`` [j] entries a lazy swap already parked (sentinel in the
    table). ``ref_of(j)`` resolves the refcount on page ``j``'s owner
    pool.
    """
    kept, park, shed = [], [], []
    for j, pid in enumerate(table):
        if pid < 0:
            shed.append(j)
        elif ref_of(j) > 1:
            kept.append((j, pid))
        else:
            park.append(j)
    return kept, park, shed


def progress_state(req, pf: Optional[PrefillProgress], *, share: bool,
                   length: int = 0, last_token: int = 0,
                   budget: int = 0) -> dict:
    """The engine-agnostic half of a swap payload: sequence progress plus
    the token key the page-in prefix re-lookup uses (mid-prefill: the
    effective prompt; in decode, conservatively the original prompt — its
    pages are the ones same-prefix traffic shares)."""
    toks = pf.toks if pf is not None else (
        tuple(int(x) for x in req.prompt) if share else None)
    state = {"lookup_toks": toks}
    if pf is not None:
        state.update(kind="prefill", prompt=pf.prompt, toks=pf.toks,
                     spans=pf.spans, chunk=pf.chunk, sharing=pf.sharing,
                     suppress_first=pf.suppress_first)
    else:
        state.update(kind="decode", length=length, last_token=last_token,
                     budget=budget)
    return state


def restore_progress(state: dict) -> Optional[PrefillProgress]:
    """Rebuild the prefill cursor from a swap payload (None: the sequence
    was preempted mid-decode — the caller restores decode fields)."""
    if state["kind"] != "prefill":
        return None
    return PrefillProgress(
        prompt=state["prompt"], toks=state["toks"], spans=state["spans"],
        chunk=state["chunk"], sharing=state["sharing"],
        suppress_first=state["suppress_first"])


def plan_page_in(park: Sequence[int], toks: Optional[tuple],
                 page_size: int,
                 lookup: Callable[[int, tuple], Optional[int]],
                 extend: Callable[[int], int],
                 rollback: Callable[[int, int], None]
                 ) -> Optional[tuple[dict, list]]:
    """Prefix-re-lookup page-in plan with rollback.

    For each parked table index ``j`` (payload order): a FULL prompt page
    first retries the prefix index (``lookup`` — a hit revives pooled
    content with zero upload, often the victim's own cached copy); misses
    allocate via ``extend``. Returns ``(filled {j: pid},
    upload [(park position, pid)])`` — only ``upload`` positions need
    their host rows written back. On PoolExhausted every page taken so
    far is rolled back through ``rollback(j, pid)`` and None is returned;
    the swap entry stays put and the caller retries next tick.
    """
    filled: dict[int, int] = {}
    upload: list[tuple[int, int]] = []
    taken: list[tuple[int, int]] = []
    try:
        for pos, j in enumerate(park):
            hit = None
            end = (j + 1) * page_size
            if toks is not None and end <= len(toks):
                hit = lookup(j, tuple(toks[:end]))
            if hit is None:
                hit = extend(j)
                upload.append((pos, hit))
            filled[j] = hit
            taken.append((j, hit))
    except PoolExhausted:
        for j, pid in taken:
            rollback(j, pid)
        return None
    return filled, upload


# ---------------------------------------------------------------------------
# Bounded fault retry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RetryGovernor:
    """Counted, backoff-governed retry policy for per-request faults.

    The engine's recompute fallback (drop pages, replay prompt + emitted
    tokens through prefill) can recover from any per-request failure —
    but unbounded it turns a persistent fault into an infinite retry
    loop. The governor counts faults per request id: each fault within
    ``max_retries`` grants another recompute attempt after a linearly
    growing delay (``backoff_ticks * attempt`` scheduler ticks — a
    transient fault clears while the request waits, a correlated one
    stops thrashing the pool); past the budget the request is
    quarantined into the FAILED terminal state. A request that finishes
    normally has its count forgotten, so a long-lived server does not
    slowly exhaust every rid's budget.
    """

    max_retries: int = 2
    backoff_ticks: int = 1
    counts: dict = dataclasses.field(default_factory=dict)

    def record_fault(self, rid: int) -> Optional[int]:
        """Count one fault against ``rid``. Returns the retry delay in
        ticks, or None when the budget is exhausted (quarantine)."""
        n = self.counts.get(rid, 0) + 1
        self.counts[rid] = n
        if n > self.max_retries:
            return None
        return self.backoff_ticks * n

    def attempts(self, rid: int) -> int:
        return self.counts.get(rid, 0)

    def forget(self, rid: int) -> None:
        self.counts.pop(rid, None)


# ---------------------------------------------------------------------------
# Lazy cold-page swap
# ---------------------------------------------------------------------------

def shed_candidates(table: Sequence[int], hot_logical: Sequence[int],
                    length: int, page_size: int,
                    ref_of: Callable[[int], int], *,
                    keep_recent: int) -> list[int]:
    """Table indices a lazy swap may park: resident, uniquely owned
    (shared pages free nothing), strictly full pages outside both the
    ``keep_recent`` newest-page window (the local attention window + the
    write page) and the current DLZS hot selection ``hot_logical`` — so
    the victim's hot-set decode output is unchanged by the shed; only
    pages the gather was already skipping leave the device."""
    hot = {int(j) for j in hot_logical if j >= 0}
    tail = length // page_size
    limit = min(len(table), tail + 1 - max(1, keep_recent))
    return [j for j in range(max(0, limit))
            if table[j] >= 0 and j not in hot and ref_of(j) == 1]


def merge_shed(state: dict, shed_state: Optional[dict],
               concat_rows: Callable[[object, object], object]) -> dict:
    """Fold a prior lazy-shed payload into a full swap payload so resume
    sees one uniform (rows, park) pair. ``concat_rows(a, b)`` joins two
    host row trees along their page axis (engine-specific layout); park
    order is preserved — resident-parked pages first, then the pages the
    earlier shed already held."""
    if shed_state is None:
        return state
    if state["rows"] is None:
        rows = shed_state["rows"]
    else:
        rows = concat_rows(state["rows"], shed_state["rows"])
    return dict(state, rows=rows,
                park=list(state["park"]) + list(shed_state["park"]))
