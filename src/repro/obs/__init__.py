"""Serving telemetry: tracing, metrics, and request timelines.

One ``Telemetry`` object bundles the three surfaces the engine stack
shares:

* ``tel.tracer`` — span/instant trace events per tick phase, exportable
  as Perfetto/Chrome ``trace_event`` JSON (see ``obs.trace``);
* ``tel.metrics`` — a ``MetricsRegistry`` of counters/gauges/histograms
  with per-SLA / per-shard labels and Prometheus text exposition;
* ``tel.timelines`` — per-request ``RequestTimeline`` lifecycles
  (submit → admit → TTFT → per-token → done/preempted).

The default everywhere is ``NULL_TELEMETRY`` — a disabled instance whose
tracer is a no-op and whose ``enabled`` flag guards every hot-path
write, so serving without telemetry costs a few attribute checks per
tick (asserted <5% overhead in tests/test_obs.py). Enable by passing a
real ``Telemetry()`` to ``LLM.from_config(..., telemetry=...)`` or
``EngineCore.attach_telemetry``. Nothing in this package touches jax:
all events are host-side; no device syncs are added to the hot path.
"""

from __future__ import annotations

import time
from typing import Optional

from repro.obs.accounting import (WatchdogReport, conservation_error,
                                  fold_snapshot, fold_traffic,
                                  reconcile_refs)
from repro.obs.audit import AuditCfg, DlzsAuditor, score_histogram
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               DEFAULT_BUCKETS)
from repro.obs.recorder import NULL_RECORDER, FlightRecorder
from repro.obs.timeline import RequestTimeline, aggregate, percentile
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer, format_table,
                             load_trace, phase_summary)


class Telemetry:
    """Live telemetry: a tracer, a metrics registry, and the per-request
    timeline table, sharing one identity the whole stack can hold."""

    enabled = True

    def __init__(self, meta: Optional[dict] = None,
                 recorder_capacity: int = 1024):
        self.meta = dict(meta or {})
        self.tracer = Tracer(self.meta)
        self.metrics = MetricsRegistry()
        self.timelines: dict[int, RequestTimeline] = {}
        self.recorder = FlightRecorder(capacity=recorder_capacity)

    def timeline(self, rid: int, sla: Optional[str] = None,
                 submit_t: Optional[float] = None) -> RequestTimeline:
        """Get-or-create the request's timeline; backfills sla/submit_t
        when first provided (the engine may see the rid before the API
        layer has registered its record)."""
        tl = self.timelines.get(rid)
        if tl is None:
            tl = RequestTimeline(rid, sla=sla,
                                 submit_t=submit_t
                                 if submit_t is not None
                                 else time.perf_counter())
            self.timelines[rid] = tl
        else:
            if tl.sla is None and sla is not None:
                tl.sla = sla
            if tl.submit_t is None and submit_t is not None:
                tl.submit_t = submit_t
        return tl

    def aggregate(self) -> dict:
        return aggregate(self.timelines.values())


class NullTelemetry(Telemetry):
    """Disabled telemetry: tracer is the shared no-op, timelines are
    throwaway objects nobody retains. ``enabled`` is False — hot paths
    check that one flag and skip all event construction."""

    enabled = False

    def __init__(self):
        super().__init__()
        self.tracer = NULL_TRACER
        self.recorder = NULL_RECORDER   # capacity-0 ring: drops everything

    def timeline(self, rid: int, sla: Optional[str] = None,
                 submit_t: Optional[float] = None) -> RequestTimeline:
        # fresh throwaway: stamps on a disabled timeline go nowhere,
        # and the table never grows
        return RequestTimeline(rid, sla=sla, submit_t=submit_t)


NULL_TELEMETRY = NullTelemetry()

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "DEFAULT_BUCKETS",
    "RequestTimeline", "aggregate", "percentile",
    "Tracer", "NullTracer", "NULL_TRACER", "load_trace", "phase_summary",
    "format_table",
    "FlightRecorder", "NULL_RECORDER",
    "AuditCfg", "DlzsAuditor", "score_histogram",
    "WatchdogReport", "conservation_error", "fold_snapshot",
    "fold_traffic", "reconcile_refs",
    "Telemetry", "NullTelemetry", "NULL_TELEMETRY",
]
