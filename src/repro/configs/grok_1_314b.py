"""grok-1 314B [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]

MoE note: 8 experts < the 16-way EP axis, so each expert's FFN is split
2-way across the data axis (virtual experts, DESIGN.md §3/MoE).
"""

from repro.core.star_attention import STARConfig
from repro.models.lm import BlockCfg, ModelCfg
from repro.models.moe import MoECfg


def config() -> ModelCfg:
    return ModelCfg(
        name="grok_1_314b",
        d_model=6144, n_layers=64, n_heads=48, n_kv=8, d_ff=32768,
        vocab=131072,
        pattern=(BlockCfg("attn", "moe"),),
        norm="rmsnorm", mlp_act="gelu", mlp_gated=True,
        moe=MoECfg(d_model=6144, d_ff=32768, n_experts=8, top_k=2,
                   act="gelu"),
        star=STARConfig(top_k_ratio=0.2),
        optimizer="adafactor", train_accum=8,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        name="grok_smoke",
        d_model=64, n_layers=2, n_heads=4, n_kv=2, d_ff=128, vocab=512,
        pattern=(BlockCfg("attn", "moe"),),
        norm="rmsnorm", mlp_act="gelu", mlp_gated=True,
        moe=MoECfg(d_model=64, d_ff=128, n_experts=8, top_k=2, act="gelu",
                   token_chunk=64),
        star=STARConfig(top_k_ratio=0.5, block_q=16, block_kv=16),
        q_chunk=64, seq_loss_chunk=64, vocab_pad_to=64,
    )
