"""Paper Fig. 17a / 18b: DLZS+SADS top-k hit rate vs SLZS+SADS, and the
accuracy <-> reduced-complexity trade-off vs top-k ratio.

Paper claims: DLZS+SADS hit rate > 97% at top-20% (SLZS < 93%); attention
output degrades gracefully down to k~0.15-0.2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import dlzs, sads
from repro.core.star_attention import STARConfig, dense_attention, \
    star_attention


def _scores(s=2048, d=64, rows=64, seed=0, peaked=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    q = jax.random.normal(ks[0], (rows, d))
    k = jax.random.normal(ks[1], (s, d))
    if peaked:  # Type I/II mixture (paper Fig. 9: ~95% of rows)
        k = k.at[: s // 16].mul(3.0)
    exact = (q @ k.T) / jnp.sqrt(float(d))
    return q, k, exact


def _hit_rate(exact, approx, ratio, n_segments=16):
    s = exact.shape[-1]
    kk = int(ratio * s) // n_segments * n_segments
    sel = sads.sads_select(approx, kk, n_segments, radius=1e9)
    hits = 0
    for r in range(exact.shape[0]):
        true_top = set(np.argsort(np.asarray(exact[r]))[-kk:].tolist())
        pred = set(np.asarray(sel.indices[r]).tolist())
        hits += len(true_top & pred) / kk
    return hits / exact.shape[0]


def run():
    q, k, exact = _scores()
    scale = 1.0 / jnp.sqrt(64.0)
    dlzs_hat = dlzs.dlzs_scores(q, dlzs.pow2_quantize(k), scale)
    slzs_hat = dlzs.slzs_scores(q, k, scale)

    for ratio in (0.05, 0.1, 0.2):
        hd = _hit_rate(exact, dlzs_hat, ratio)
        hs = _hit_rate(exact, slzs_hat, ratio)
        emit(f"fig17a_hit_top{int(ratio * 100)}", 0.0,
             f"dlzs={hd:.1%} slzs={hs:.1%} delta={hd - hs:+.1%} "
             f"(paper: dlzs>97% slzs<93% @20%)")

    # Fig. 18b: accuracy proxy (attention output error) vs reduced complexity
    ksz, d = 2048, 64
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    qf = jax.random.normal(keys[0], (256, d))
    kf = jax.random.normal(keys[1], (ksz, d)).at[: ksz // 16].mul(3.0)
    vf = jax.random.normal(keys[2], (ksz, d))
    ref = dense_attention(qf, kf, vf, causal=False)
    for ratio in (0.1, 0.15, 0.2, 0.3, 0.5):
        cfg = STARConfig(top_k_ratio=ratio, block_q=128, block_kv=128,
                         radius=1e9)  # isolate the top-k axis (the sphere
        #                               saturates selection on peaked rows)
        out = star_attention(qf, kf, vf, cfg, causal=False)
        err = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        emit(f"fig18b_tradeoff_k{int(ratio * 100)}", 0.0,
             f"rel_err={err:.3f} reduced_complexity={1 - ratio:.0%}")
