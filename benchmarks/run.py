# One module per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   Fig. 5   -> fa_overhead            (FA-2 tile-update overhead, SU-FA cut)
#   Fig. 16/18a -> complexity_reduction (DLZS/SADS/SU-FA equivalent-adds)
#   Fig. 17a/18b -> topk_hit            (DLZS vs SLZS hit rate; acc<->RC)
#   Fig. 19/20/22a -> throughput        (dense vs STAR wall clock + traffic)
#   Fig. 23/24 -> spatial               (DRAttention/MRCA mesh simulation)
#   Table III -> roofline_table         (per-cell roofline from the dry-run)
#   (beyond-paper) -> serving           (paged KV cache vs dense slot cache:
#                                        TTFT, tok/s, KV footprint ratio)

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (complexity_reduction, fa_overhead,
                            roofline_table, serving, spatial, throughput,
                            topk_hit)

    print("name,us_per_call,derived")
    modules = [fa_overhead, complexity_reduction, topk_hit, throughput,
               spatial, roofline_table, serving]
    failed = []
    for mod in modules:
        try:
            mod.run()
        except Exception:  # noqa: BLE001 — report per-table, keep going
            traceback.print_exc()
            failed.append(mod.__name__)
    try:
        throughput.run_kernels()
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        failed.append("throughput.run_kernels")
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
