"""Spatial-architecture demo: DRAttention ring on 8 simulated devices and
the MRCA schedule that realizes it on a mesh NoC without wrap-around links.

Run:  PYTHONPATH=src python examples/spatial_ring_demo.py
(This script re-execs itself with 8 fake XLA devices.)
"""

import os
import sys

if os.environ.get("_SPATIAL_DEMO") != "1":
    os.environ["_SPATIAL_DEMO"] = "1"
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrca
from repro.core.dr_attention import dr_attention
from repro.core.star_attention import dense_attention


def main():
    n = 8
    mesh = jax.make_mesh((n,), ("sp",))
    s, d = 1024, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (s, d), jnp.float32) for kk in ks)

    out = jax.jit(lambda q, k, v: dr_attention(q, k, v, mesh=mesh,
                                               axis="sp", causal=True)
                  )(q, k, v)
    ref = dense_attention(q, k, v, causal=True)
    err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
    print(f"DRAttention on {n} seq-sharded devices: max |err| vs dense = "
          f"{err:.2e}")
    print("  (Q sub-blocks rotate with their (m, l, o) partial-softmax "
          "state; KV stays resident — half the ring traffic of "
          "RingAttention-KV)")

    # MRCA: the same ring as a wrap-around-free mesh schedule
    sim = mrca.simulate(n)
    cost_mrca = mrca.schedule_cost(mrca.mrca_schedule(n))
    cost_naive = mrca.schedule_cost(mrca.naive_ring_schedule(n))
    print(f"MRCA on a 1x{n} mesh: every CU computed all {n} chunks in "
          f"{n} steps, max {sim.max_chunks_stored} chunks stored, "
          f"{sim.link_conflicts} link conflicts")
    print(f"  latency vs naive ring-on-mesh: {cost_mrca['latency_ns']:.0f} "
          f"vs {cost_naive['latency_ns']:.0f} ns "
          f"({cost_naive['latency_ns'] / cost_mrca['latency_ns']:.1f}x)")


if __name__ == "__main__":
    main()
