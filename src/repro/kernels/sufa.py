"""SU-FA Pallas TPU kernel — sorted-updating block-sparse flash attention.

The cross-stage contract: SADS hands this kernel, per query tile, the list
of selected KV tiles in DESCENDING predicted-max order (+ validity and
in-tile masks). The kernel streams ONLY those tiles; with ``strict=False``
(the paper's descend-updating fast path) the running max is frozen after the
first — highest — tile, eliminating FA-2's per-tile max refresh and the
o/l rescale multiplies (Fig. 11b).

KV tiles are pre-gathered by XLA into [BH, n_qt, keep, Bc, d] so the
BlockSpec index maps stay static (the selection indices were consumed by the
gather). The grid is (BH, n_qt, keep) with the keep dim innermost; (m, l, o)
accumulate in revisited VMEM output blocks exactly like kernels/flash.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _sufa_kernel(q_ref, kg_ref, vg_ref, mask_ref, o_ref, m_ref, l_ref, *,
                 scale: float, strict: bool):
    ji = pl.program_id(2)

    @pl.when(ji == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [Bq, d]
    k = kg_ref[0, 0, 0].astype(jnp.float32)          # [Bc, d]
    v = vg_ref[0, 0, 0].astype(jnp.float32)
    mask = mask_ref[0, 0, 0] != 0                    # [Bq, Bc]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    tile_max = s.max(axis=-1)                        # [Bq]
    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]

    if strict:
        # exact online softmax (rescale like FA-2; order-independent)
        m_new = jnp.maximum(m_prev, tile_max)
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
    else:
        # descend updating: tiles arrive max-first, so the max set by tile 0
        # is final — no comparison against m_prev, no rescale multiply.
        first = m_prev <= NEG_INF / 2
        m_new = jnp.where(first, tile_max, m_prev)
        alpha = jnp.ones_like(m_prev)

    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    l_new = l_prev * alpha + p.sum(axis=-1)
    o_new = o_ref[0, 0] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new
    o_ref[0, 0] = o_new


def sufa_attention(q: jax.Array, kg: jax.Array, vg: jax.Array,
                   mask: jax.Array, *, scale: float | None = None,
                   strict: bool = False, interpret: bool = True):
    """q [BH, T, d]; kg/vg [BH, n_qt, keep, Bc, d] (gathered, desc order);
    mask [BH, n_qt, keep, Bq, Bc] (validity x causal x sphere) -> [BH, T, d].
    """
    bh, t, d = q.shape
    _, n_qt, keep, block_kv, _ = kg.shape
    block_q = t // n_qt
    scale = scale or (1.0 / math.sqrt(d))

    kernel = functools.partial(_sufa_kernel, scale=scale, strict=strict)
    grid = (bh, n_qt, keep)
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, 1, block_kv, d),
                         lambda b, i, j: (b, i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, block_kv, d),
                         lambda b, i, j: (b, i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, block_q, block_kv),
                         lambda b, i, j: (b, i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n_qt, block_q, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, n_qt, block_q), jnp.float32),
            jax.ShapeDtypeStruct((bh, n_qt, block_q), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(bh, n_qt, block_q, d), kg, vg,
      mask.astype(jnp.int8))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(bh, t, d).astype(q.dtype)
