"""SeamlessM4T-large-v2 [audio] — 24L(+24 enc) d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206, encoder-decoder, multimodal.
[arXiv:2308.11596; hf]

The speech frontend is a STUB per spec: ``input_specs()`` provides
precomputed frame embeddings for the encoder; the decoder consumes tokens.
STAR applies to decoder self- and (dense) cross-attention.
"""

from repro.core.star_attention import STARConfig
from repro.models.lm import BlockCfg, ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        name="seamless_m4t_large_v2",
        d_model=1024, n_layers=24, n_heads=16, n_kv=16, d_ff=8192,
        vocab=256206,
        pattern=(BlockCfg("attn", "dense", cross_attn=True),),
        enc_layers=24,
        norm="layernorm", mlp_act="relu", mlp_gated=False,
        rope_fraction=0.0,   # seamless uses learned/relative pos; frontend stub
        star=STARConfig(top_k_ratio=0.2),
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        name="seamless_smoke",
        d_model=64, n_layers=2, n_heads=4, n_kv=4, d_ff=128, vocab=512,
        pattern=(BlockCfg("attn", "dense", cross_attn=True),),
        enc_layers=2,
        norm="layernorm", mlp_act="relu", mlp_gated=False,
        rope_fraction=0.0,
        star=STARConfig(top_k_ratio=0.5, block_q=16, block_kv=16),
        q_chunk=64, seq_loss_chunk=64, vocab_pad_to=64,
    )
